//! Pareto distribution model of task execution times (paper §3.1).
//!
//! `F_X(x) = 1 − (x/β)^(−α)` for x ≥ β.  MLE fitting (Eqs. 2–3), the
//! straggler threshold `K = k·αβ/(α−1)` (a multiple of the distribution
//! mean), and the expected straggler count `E_S = q·(K/β)^(−α)` (Eq. 4).

use anyhow::{ensure, Result};

/// Fitted / predicted Pareto parameters.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Pareto {
    pub alpha: f64,
    pub beta: f64,
}

impl Pareto {
    pub fn new(alpha: f64, beta: f64) -> Result<Self> {
        ensure!(alpha > 0.0 && beta > 0.0, "Pareto requires α, β > 0 (got α={alpha}, β={beta})");
        Ok(Self { alpha, beta })
    }

    /// Maximum-likelihood fit (Eq. 3): β̂ = min(X), α̂ = q / Σ log(X_i/β̂).
    pub fn mle(samples: &[f64]) -> Result<Self> {
        ensure!(!samples.is_empty(), "MLE needs at least one sample");
        ensure!(samples.iter().all(|&x| x > 0.0), "task times must be positive");
        let beta = samples.iter().cloned().fold(f64::INFINITY, f64::min);
        let q = samples.len() as f64;
        let log_sum: f64 = samples.iter().map(|&x| (x / beta).ln()).sum();
        // All-equal samples give log_sum = 0 (degenerate, infinite α); clamp.
        let alpha = if log_sum <= 1e-12 { 1e6 } else { q / log_sum };
        Ok(Self { alpha, beta })
    }

    /// CDF (Eq. 1).
    pub fn cdf(&self, x: f64) -> f64 {
        if x < self.beta {
            0.0
        } else {
            1.0 - (x / self.beta).powf(-self.alpha)
        }
    }

    /// Mean αβ/(α−1); defined only for α > 1.
    pub fn mean(&self) -> Option<f64> {
        (self.alpha > 1.0).then(|| self.alpha * self.beta / (self.alpha - 1.0))
    }

    /// Straggler threshold `K = k · mean` (paper §3.1, k = 1.5 default).
    /// For α ≤ 1 the mean is undefined; the threshold degrades to k·β·10
    /// (a deep-tail cutoff) so mitigation still engages on pathological
    /// fits instead of dividing by zero.
    pub fn straggler_threshold(&self, k: f64) -> f64 {
        match self.mean() {
            Some(mean) => k * mean,
            None => k * self.beta * 10.0,
        }
    }

    /// Expected number of stragglers among `q` tasks (Eq. 4):
    /// `E_S = q · (K/β)^(−α)` = q · P(X > K).
    pub fn expected_stragglers(&self, q: usize, k: f64) -> f64 {
        let kk = self.straggler_threshold(k);
        if kk <= self.beta {
            return q as f64; // threshold below support: everything "straggles"
        }
        q as f64 * (kk / self.beta).powf(-self.alpha)
    }

    /// Tail probability P(X > x).
    pub fn tail(&self, x: f64) -> f64 {
        1.0 - self.cdf(x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ptest;
    use crate::util::rng::Pcg;

    #[test]
    fn mle_exact_beta() {
        let p = Pareto::mle(&[3.0, 1.5, 2.0, 9.0]).unwrap();
        assert_eq!(p.beta, 1.5);
        assert!(p.alpha > 0.0);
    }

    #[test]
    fn mle_rejects_empty_and_nonpositive() {
        assert!(Pareto::mle(&[]).is_err());
        assert!(Pareto::mle(&[1.0, -2.0]).is_err());
    }

    #[test]
    fn mle_degenerate_all_equal() {
        let p = Pareto::mle(&[2.0, 2.0, 2.0]).unwrap();
        assert!(p.alpha >= 1e5); // effectively a point mass
        assert_eq!(p.beta, 2.0);
    }

    #[test]
    fn cdf_support_and_monotone() {
        let p = Pareto::new(2.0, 1.0).unwrap();
        assert_eq!(p.cdf(0.5), 0.0);
        assert_eq!(p.cdf(1.0), 0.0);
        assert!((p.cdf(2.0) - 0.75).abs() < 1e-12);
        assert!(p.cdf(3.0) > p.cdf(2.0));
    }

    #[test]
    fn mean_matches_formula() {
        let p = Pareto::new(3.0, 2.0).unwrap();
        assert!((p.mean().unwrap() - 3.0).abs() < 1e-12);
        assert!(Pareto::new(0.9, 1.0).unwrap().mean().is_none());
    }

    #[test]
    fn expected_stragglers_eq4() {
        // α=2, β=1 → mean 2, K = 1.5·2 = 3, E_S = q·3^{−2} = q/9.
        let p = Pareto::new(2.0, 1.0).unwrap();
        let es = p.expected_stragglers(90, 1.5);
        assert!((es - 10.0).abs() < 1e-9, "{es}");
    }

    #[test]
    fn expected_stragglers_monotone_in_k() {
        let p = Pareto::new(2.5, 1.0).unwrap();
        let e1 = p.expected_stragglers(100, 1.2);
        let e2 = p.expected_stragglers(100, 1.5);
        let e3 = p.expected_stragglers(100, 2.0);
        assert!(e1 > e2 && e2 > e3, "{e1} {e2} {e3}");
    }

    #[test]
    fn property_mle_roundtrip() {
        // sample → fit recovers parameters within tolerance for large q.
        ptest::check("pareto-mle-roundtrip", 25, |rng: &mut Pcg| {
            let alpha = rng.range(1.3, 4.0);
            let beta = rng.range(0.2, 5.0);
            let samples: Vec<f64> = (0..8000).map(|_| rng.pareto(alpha, beta)).collect();
            let fit = Pareto::mle(&samples).map_err(|e| e.to_string())?;
            if (fit.alpha - alpha).abs() > 0.25 * alpha {
                return Err(format!("alpha {alpha} fit {}", fit.alpha));
            }
            if (fit.beta - beta).abs() > 0.05 * beta {
                return Err(format!("beta {beta} fit {}", fit.beta));
            }
            Ok(())
        });
    }

    #[test]
    fn property_expected_stragglers_matches_empirical() {
        // E_S/q ≈ empirical fraction of samples above K.
        ptest::check("es-empirical", 15, |rng: &mut Pcg| {
            let alpha = rng.range(1.5, 3.5);
            let beta = rng.range(0.5, 2.0);
            let p = Pareto::new(alpha, beta).unwrap();
            let k = 1.5;
            let threshold = p.straggler_threshold(k);
            let n = 40000;
            let hits = (0..n).filter(|_| rng.pareto(alpha, beta) > threshold).count();
            let expect = p.expected_stragglers(n, k);
            let diff = (hits as f64 - expect).abs() / n as f64;
            if diff > 0.01 {
                return Err(format!("empirical {hits} vs expected {expect}"));
            }
            Ok(())
        });
    }
}
