//! start-sim launcher: simulate / experiment / info subcommands.
use anyhow::Result;

fn main() -> Result<()> {
    start_sim::launcher_main()
}
