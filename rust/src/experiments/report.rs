//! Tabular report formatting + JSON dump for experiment results.

use crate::util::json::Json;

/// A simple column-aligned table with a JSON dump.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Render column-aligned text.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = format!("== {} ==\n", self.title);
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// JSON form for machine-readable dumps.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("title", Json::str(self.title.clone())),
            (
                "headers",
                Json::Arr(self.headers.iter().map(|h| Json::str(h.clone())).collect()),
            ),
            (
                "rows",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| Json::Arr(r.iter().map(|c| Json::str(c.clone())).collect()))
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(vec!["a".into(), "1.5".into()]);
        t.row(vec!["longer".into(), "2".into()]);
        let s = t.render();
        assert!(s.contains("demo"));
        assert!(s.lines().count() >= 4);
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
