//! Ablations of START's design choices (DESIGN.md §4, beyond the paper's
//! own figures):
//!
//! * dynamic k adaptation on/off (paper §4.3 "dynamically change k")
//! * underlying scheduler (A3C-R2N2 surrogate vs random/RR/min-min —
//!   paper §4.5 argues the scheduler choice matters)
//! * mitigation strategy: full START vs speculation-only vs re-run-only
//!   (paper §3.3 motivates having both)
//! * fused-rollout window: T = 5 vs T = 1 (does the LSTM memory help?)

use crate::config::{SchedulerKind, SimConfig, Technique};
use crate::coordinator::Cell;
use crate::experiments::common::*;
use crate::experiments::report::Table;
use anyhow::Result;
use std::path::PathBuf;

pub fn ablation(
    profile: Profile,
    threads: usize,
    art_dir: &PathBuf,
    opts: &ExpOpts,
) -> Result<ExperimentResult> {
    let mut base = profile.base_config();
    base.technique = Technique::Start;
    let seeds = [42u64, 43, 44];

    let variants: Vec<(&str, Box<dyn Fn(&mut SimConfig)>)> = vec![
        ("START (full)", Box::new(|_: &mut SimConfig| {})),
        ("no dynamic k", Box::new(|c: &mut SimConfig| c.dynamic_k = false)),
        ("k = 1.0", Box::new(|c: &mut SimConfig| {
            c.dynamic_k = false;
            c.k_straggler = 1.0;
        })),
        ("k = 2.0", Box::new(|c: &mut SimConfig| {
            c.dynamic_k = false;
            c.k_straggler = 2.0;
        })),
        ("window T = 1", Box::new(|c: &mut SimConfig| c.window_steps = 1)),
        ("predict every 5", Box::new(|c: &mut SimConfig| c.predict_every = 5)),
        ("sched: random", Box::new(|c: &mut SimConfig| c.scheduler = SchedulerKind::Random)),
        ("sched: round-robin", Box::new(|c: &mut SimConfig| c.scheduler = SchedulerKind::RoundRobin)),
        ("sched: min-min", Box::new(|c: &mut SimConfig| c.scheduler = SchedulerKind::MinMin)),
        ("no mitigation", Box::new(|c: &mut SimConfig| c.technique = Technique::None)),
    ];

    let mut cells = Vec::new();
    for (label, apply) in &variants {
        for &seed in &seeds {
            let mut cfg = base.clone();
            cfg.seed = seed;
            apply(&mut cfg);
            cells.push(Cell { label: format!("{label}|START|{seed}"), cfg });
        }
    }
    let results = execute("ablation", cells, threads, art_dir, opts)?;

    let exec = group_results(&results, |m| m.avg_execution_time());
    let sla = group_results(&results, |m| m.sla_violation_rate());
    let f1 = group_results(&results, |m| m.confusion.f1());
    let mape = group_results(&results, |m| m.straggler_mape());

    let mut table = Table::new(
        "Ablation — START design choices (mean of 3 seeds)",
        &["variant", "exec (s)", "SLA viol %", "F1", "MAPE %"],
    );
    for (label, _) in &variants {
        let key = label.to_string();
        let get = |g: &std::collections::BTreeMap<String, std::collections::BTreeMap<String, f64>>| {
            g.get(&key).and_then(|m| m.get("START")).copied().unwrap_or(f64::NAN)
        };
        table.row(vec![
            key.clone(),
            format!("{:.1}", get(&exec)),
            format!("{:.2}", 100.0 * get(&sla)),
            format!("{:.3}", get(&f1)),
            format!("{:.1}", get(&mape)),
        ]);
    }
    let raw = results.iter().map(|(l, m)| (l.clone(), metrics_json(m))).collect();
    Ok(ExperimentResult { id: "ablation", tables: vec![table], raw })
}
