//! Shared plumbing for the per-figure experiment modules: profile scaling
//! (fast vs paper-scale), technique sweeps, result persistence.

use crate::config::{SimConfig, Technique};
use crate::coordinator::{failure_summary, run_many_cells, Cell, RunOpts, DEFAULT_RETRIES};
use crate::experiments::report::Table;
use crate::sim::metrics::RunMetrics;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::time::Duration;

/// Experiment size profile.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Profile {
    /// Scaled-down cloud (~100 VMs, 48 intervals): minutes, same shape.
    Fast,
    /// Paper scale (400 VMs, 288 intervals = 24 h, 5000 cloudlets).
    Paper,
}

impl Profile {
    pub fn base_config(self) -> SimConfig {
        match self {
            Profile::Paper => SimConfig::paper_defaults(),
            Profile::Fast => {
                let mut cfg = SimConfig::paper_defaults();
                cfg.pm_counts = vec![6, 4, 2]; // 6·12+4·6+2·2 = 100 VMs
                cfg.n_intervals = 48;
                cfg.n_workloads = 600;
                cfg
            }
        }
    }

    /// Workload sweep points for Fig. 7 (scaled for the profile).
    pub fn workload_points(self) -> Vec<usize> {
        match self {
            Profile::Paper => vec![1000, 2000, 3000, 4000, 5000],
            Profile::Fast => vec![150, 300, 450, 600, 750],
        }
    }

    /// Reserved-utilization sweep for Figs. 6/8.  The fast profile's
    /// smaller fleet saturates (capacity floor) beyond ~40 % reservation,
    /// compressing all techniques together, so its sweep stays below the
    /// knee; `--paper` uses the paper's 20–80 %.
    pub fn reserved_points(self) -> Vec<f64> {
        match self {
            Profile::Paper => vec![0.2, 0.4, 0.6, 0.8],
            Profile::Fast => vec![0.1, 0.2, 0.3, 0.4],
        }
    }
}

/// Observability + resilience options threaded from the experiment CLI
/// into every figure's runner (DESIGN.md §10, §12).
#[derive(Clone)]
pub struct ExpOpts {
    /// When set, each cell streams a JSONL event trace to
    /// `<dir>/<figure id>/<sanitized cell label>.jsonl`.
    pub trace_dir: Option<PathBuf>,
    /// Print a per-figure phase-timing table (profiler counters).
    pub profile: bool,
    /// Crash-safe per-figure results journal directory
    /// (`<dir>/<figure id>.results.jsonl`); `None` disables journaling.
    pub journal_dir: Option<PathBuf>,
    /// `--resume`: skip cells already present in the figure's journal.
    pub resume: bool,
    /// `--keep-going`: run every cell, report failures, build tables
    /// from the cells that succeeded.
    pub keep_going: bool,
    /// `--retries N`: extra attempts per cell.
    pub retries: u32,
    /// `--cell-timeout SECS`: per-cell wall-clock deadline.
    pub cell_timeout: Option<Duration>,
    /// `--compact`: after a fully journaled figure, rewrite its journal
    /// keeping only the last record per `(label, digest)` key.
    pub compact: bool,
}

impl Default for ExpOpts {
    fn default() -> ExpOpts {
        ExpOpts {
            trace_dir: None,
            profile: false,
            journal_dir: None,
            resume: false,
            keep_going: false,
            retries: DEFAULT_RETRIES,
            cell_timeout: None,
            compact: false,
        }
    }
}

impl ExpOpts {
    /// Lower the experiment-level options into coordinator [`RunOpts`]
    /// for one figure.
    pub fn run_opts(&self, id: &str) -> RunOpts {
        RunOpts {
            trace_dir: self.trace_dir.as_ref().map(|d| d.join(id)),
            journal: self.journal_dir.as_ref().map(|d| d.join(format!("{id}.results.jsonl"))),
            resume: self.resume,
            keep_going: self.keep_going,
            retries: self.retries,
            cell_timeout: self.cell_timeout,
            compact: self.compact,
            ..RunOpts::default()
        }
    }
}

/// Shared figure runner: cells → results (+ raw dump entries), through
/// the fault-tolerant coordinator.  `--trace <dir>` streams one JSONL
/// file per cell into `<dir>/<figure id>/`, the journal makes the figure
/// resumable (`--resume`), and `--keep-going` degrades to
/// partial tables (failed cells reported on stderr, their grid points
/// rendered as NaN) instead of aborting the figure.
pub fn execute(
    id: &str,
    cells: Vec<Cell>,
    threads: usize,
    art_dir: &Path,
    opts: &ExpOpts,
) -> Result<Vec<(String, RunMetrics)>> {
    let run_opts = opts.run_opts(id);
    let outcomes = run_many_cells(cells, threads, art_dir.to_path_buf(), run_opts)?;
    let restored = outcomes.iter().filter(|o| o.from_journal).count();
    if restored > 0 {
        println!("[{id}] resume: {restored} of {} cells restored from journal", outcomes.len());
    }
    let summary = failure_summary(&outcomes);
    let mut results = Vec::with_capacity(outcomes.len());
    let mut first_err = None;
    for o in outcomes {
        match o.result {
            Ok(m) => results.push((o.label, m)),
            Err(e) => {
                first_err.get_or_insert(e);
            }
        }
    }
    if let Some(s) = &summary {
        if opts.keep_going {
            eprintln!("[{id}] continuing with partial results — {s}");
        }
    }
    if let Some(e) = first_err {
        if !opts.keep_going {
            return Err(e);
        }
    }
    if opts.profile {
        println!("{}", phase_table(id, &results).render());
    }
    Ok(results)
}

/// Aggregate the phase profiler across a figure's result set: the
/// per-figure timing table printed under `--profile`.
pub fn phase_table(id: &str, results: &[(String, RunMetrics)]) -> Table {
    use crate::sim::trace::Phase;
    let mut t = Table::new(
        &format!("{id} — phase wall time summed over {} cells", results.len()),
        &["phase", "seconds", "calls"],
    );
    let mut total = 0.0;
    for p in Phase::ALL {
        let secs: f64 = results.iter().map(|(_, m)| m.profile.seconds(p)).sum();
        let calls: u64 = results.iter().map(|(_, m)| m.profile.calls(p)).sum();
        total += secs;
        t.row(vec![p.name().to_string(), format!("{secs:.4}"), calls.to_string()]);
        if p == Phase::Predict {
            // Manager-reported sub-spans: a breakdown of the predict row
            // (not added to the total), present only when instrumented.
            for (i, name) in crate::sim::trace::PredictSpans::NAMES.iter().enumerate() {
                let s: f64 = results.iter().map(|(_, m)| m.profile.predict_span(i).0).sum();
                let c: u64 = results.iter().map(|(_, m)| m.profile.predict_span(i).1).sum();
                if c > 0 {
                    t.row(vec![format!("  predict/{name}"), format!("{s:.4}"), c.to_string()]);
                }
            }
        }
    }
    t.row(vec!["total".to_string(), format!("{total:.4}"), "".to_string()]);
    t
}

/// Results of one experiment: rendered tables + raw per-cell metrics.
pub struct ExperimentResult {
    pub id: &'static str,
    pub tables: Vec<Table>,
    /// label → selected scalar metrics for the JSON dump.
    pub raw: BTreeMap<String, Json>,
}

impl ExperimentResult {
    pub fn print(&self) {
        for t in &self.tables {
            println!("{}", t.render());
        }
    }

    /// Persist to `<out_dir>/<id>.json`.
    pub fn save(&self, out_dir: &Path) -> Result<PathBuf> {
        std::fs::create_dir_all(out_dir)
            .with_context(|| format!("creating {}", out_dir.display()))?;
        let path = out_dir.join(format!("{}.json", self.id));
        let doc = Json::obj(vec![
            ("id", Json::str(self.id)),
            ("tables", Json::Arr(self.tables.iter().map(|t| t.to_json()).collect())),
            ("raw", Json::Obj(self.raw.clone())),
        ]);
        std::fs::write(&path, doc.dump())
            .with_context(|| format!("writing {}", path.display()))?;
        Ok(path)
    }
}

/// Standard scalar extraction for the JSON dump.
pub fn metrics_json(m: &RunMetrics) -> Json {
    let (cpu, ram, disk, net) = m.avg_utils();
    Json::obj(vec![
        ("jobs_done", Json::Num(m.jobs_done as f64)),
        ("tasks_done", Json::Num(m.tasks_done as f64)),
        ("avg_exec_time_s", Json::Num(m.avg_execution_time())),
        ("energy_kwh", Json::Num(m.total_energy_kwh())),
        ("contention", Json::Num(m.avg_contention())),
        ("sla_violation_rate", Json::Num(m.sla_violation_rate())),
        ("cpu_util", Json::Num(cpu)),
        ("ram_util", Json::Num(ram)),
        ("disk_util", Json::Num(disk)),
        ("net_util", Json::Num(net)),
        ("mape", Json::Num(m.straggler_mape())),
        ("f1", Json::Num(m.confusion.f1())),
        ("overhead_s", Json::Num(m.manager_overhead_s())),
        ("phases", m.profile.to_json()),
        ("speculations", Json::Num(m.speculations as f64)),
        ("reruns", Json::Num(m.reruns as f64)),
        ("exec_var", Json::Num(m.exec_summary().variance())),
        ("exec_p95", Json::Num(m.exec_summary().p95)),
    ])
}

/// Build the (technique × sweep) cell grid used by Figs. 6–8.
pub fn technique_sweep_cells(
    base: &SimConfig,
    techniques: &[Technique],
    sweep: &[(String, Box<dyn Fn(&mut SimConfig)>)],
    seeds: &[u64],
) -> Vec<Cell> {
    let mut cells = Vec::new();
    for (sweep_label, apply) in sweep {
        for &t in techniques {
            for &seed in seeds {
                let mut cfg = base.clone();
                cfg.technique = t;
                cfg.seed = seed;
                apply(&mut cfg);
                cells.push(Cell {
                    label: format!("{sweep_label}|{}|{seed}", t.name()),
                    cfg,
                });
            }
        }
    }
    cells
}

/// Group `label = "<sweep>|<technique>|<seed>"` results, averaging seeds.
/// Returns sweep → technique → averaged metric map.
pub fn group_results(
    results: &[(String, RunMetrics)],
    metric: impl Fn(&RunMetrics) -> f64,
) -> BTreeMap<String, BTreeMap<String, f64>> {
    let mut acc: BTreeMap<String, BTreeMap<String, (f64, usize)>> = BTreeMap::new();
    for (label, m) in results {
        let mut parts = label.split('|');
        let (Some(sweep), Some(tech)) = (parts.next(), parts.next()) else {
            // A label outside the `<sweep>|<technique>|<seed>` scheme has
            // no grid point; skip it rather than panic mid-reduction.
            continue;
        };
        let (sweep, tech) = (sweep.to_string(), tech.to_string());
        let e = acc.entry(sweep).or_default().entry(tech).or_insert((0.0, 0));
        e.0 += metric(m);
        e.1 += 1;
    }
    acc.into_iter()
        .map(|(s, ts)| {
            (s, ts.into_iter().map(|(t, (sum, n))| (t, sum / n as f64)).collect())
        })
        .collect()
}

/// Render a sweep × technique table for one metric.
pub fn sweep_table(
    title: &str,
    sweep_order: &[String],
    techniques: &[Technique],
    grouped: &BTreeMap<String, BTreeMap<String, f64>>,
    fmt: impl Fn(f64) -> String,
) -> Table {
    let mut headers = vec!["sweep".to_string()];
    headers.extend(techniques.iter().map(|t| t.name().to_string()));
    let mut table = Table::new(title, &headers.iter().map(String::as_str).collect::<Vec<_>>());
    for s in sweep_order {
        let mut row = vec![s.clone()];
        for t in techniques {
            let v = grouped.get(s).and_then(|m| m.get(t.name())).copied().unwrap_or(f64::NAN);
            row.push(fmt(v));
        }
        table.row(row);
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profiles_scale() {
        let fast = Profile::Fast.base_config();
        let paper = Profile::Paper.base_config();
        assert!(fast.total_vms() < paper.total_vms());
        assert_eq!(paper.total_vms(), 400);
        assert_eq!(fast.total_vms(), 100);
    }

    #[test]
    fn grouping_averages_seeds() {
        let mut m1 = RunMetrics::default();
        m1.exec_times = vec![10.0];
        let mut m2 = RunMetrics::default();
        m2.exec_times = vec![20.0];
        let results = vec![
            ("20%|START|1".to_string(), m1),
            ("20%|START|2".to_string(), m2),
        ];
        let g = group_results(&results, |m| m.avg_execution_time());
        assert!((g["20%"]["START"] - 15.0).abs() < 1e-12);
    }
}
