//! Experiment harness — regenerates every table and figure in the paper's
//! evaluation (DESIGN.md §4).  `start-sim experiment <fig2|fig5|fig6|fig7|
//! fig8|fig9|fig10|headline|all> [--paper] [--threads N] [--out results]
//! [--trace DIR] [--profile]` — the last two stream per-cell JSONL event
//! traces and print per-figure phase-timing tables (DESIGN.md §10).
//!
//! Resilience knobs (DESIGN.md §12): every figure journals completed
//! cells to `<out>/journal/<id>.results.jsonl`; `--resume` skips the
//! journaled cells of an interrupted run (bit-identical tables),
//! `--keep-going` builds partial tables instead of aborting on the first
//! failed cell, `--retries N` and `--cell-timeout SECS` bound transient
//! failures and hung cells, and `--compact` rewrites each figure's
//! journal after the batch keeping only the last record per cell key.
pub mod ablation;
pub mod common;
pub mod figures;
pub mod report;
pub use common::{ExpOpts, ExperimentResult, Profile};
pub use report::Table;

use crate::util::cli::Args;
use anyhow::Result;
use std::path::PathBuf;

/// Dispatch `start-sim experiment <id>`.
pub fn run_from_cli(args: &Args) -> Result<()> {
    let which = args.positional.first().map(String::as_str).unwrap_or("all");
    let profile = if args.flag("paper") { Profile::Paper } else { Profile::Fast };
    let threads = args.usize_or(
        "threads",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
    )?;
    let out_dir = PathBuf::from(args.str_or("out", "results"));
    let art_dir = crate::find_artifact_dir();
    let retries = args.usize_or("retries", crate::coordinator::DEFAULT_RETRIES as usize)?;
    let opts = ExpOpts {
        trace_dir: args.opt_path("trace"),
        profile: args.flag("profile"),
        journal_dir: Some(out_dir.join("journal")),
        resume: args.flag("resume"),
        keep_going: args.flag("keep-going"),
        retries: u32::try_from(retries).unwrap_or(u32::MAX),
        cell_timeout: match args.opt_f64("cell-timeout")? {
            // `from_secs_f64` panics on non-finite/negative input.
            Some(s) if s.is_finite() && s > 0.0 => {
                Some(std::time::Duration::from_secs_f64(s))
            }
            Some(s) => anyhow::bail!("--cell-timeout wants positive seconds, got {s}"),
            None => None,
        },
        compact: args.flag("compact"),
    };
    let ids: Vec<&str> = if which == "all" {
        vec!["fig2", "fig5", "fig6", "fig7", "fig8", "fig9", "fig10", "headline", "ablation"]
    } else {
        vec![which]
    };
    for id in ids {
        let t0 = std::time::Instant::now();
        let result = match id {
            "fig2" => figures::fig2(profile, threads, &art_dir, &opts)?,
            "fig5" => figures::fig5(profile, threads, &art_dir, &opts)?,
            "fig6" => figures::fig6(profile, threads, &art_dir, &opts)?,
            "fig7" => figures::fig7(profile, threads, &art_dir, &opts)?,
            "fig8" => figures::fig8(profile, threads, &art_dir, &opts)?,
            "fig9" => figures::fig9(profile, threads, &art_dir, &opts)?,
            "fig10" => figures::fig10(profile, threads, &art_dir, &opts)?,
            "headline" => figures::headline(profile, threads, &art_dir, &opts)?,
            "ablation" => ablation::ablation(profile, threads, &art_dir, &opts)?,
            other => anyhow::bail!("unknown experiment {other:?}"),
        };
        result.print();
        let path = result.save(&out_dir)?;
        println!("[{id}] saved {} ({:.1}s)\n", path.display(), t0.elapsed().as_secs_f64());
    }
    Ok(())
}
