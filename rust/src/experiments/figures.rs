//! The per-figure experiment implementations (DESIGN.md §4).
//!
//! Each `figN` function regenerates the corresponding paper artifact:
//! same axes, same technique set, same metrics — absolute values differ
//! (our substrate is a simulator) but the *shape* is the reproduction
//! target.

use crate::config::{SimConfig, Technique};
use crate::coordinator::Cell;
use crate::experiments::common::*;
use crate::experiments::report::Table;
use crate::sim::metrics::RunMetrics;
use crate::util::json::Json;
use crate::util::stats::Summary;
use anyhow::Result;
use std::collections::BTreeMap;
use std::path::PathBuf;

fn pct(v: f64) -> String {
    format!("{:.1}%", 100.0 * v)
}

fn f1s(v: f64) -> String {
    format!("{v:.3}")
}

fn secs(v: f64) -> String {
    format!("{v:.1}")
}

fn kwh(v: f64) -> String {
    format!("{v:.2}")
}

fn raw_map(results: &[(String, RunMetrics)]) -> BTreeMap<String, Json> {
    results.iter().map(|(l, m)| (l.clone(), metrics_json(m))).collect()
}

// ------------------------------------------------------------------ FIG 2

/// Fig. 2: F1 of straggler classification vs the hyper-parameters k
/// (straggler multiple), I (inference period) and T (window length).
/// Expectation: k = 1.5, I = 1, T = 5 is the grid optimum.
pub fn fig2(
    profile: Profile,
    threads: usize,
    art_dir: &PathBuf,
    opts: &ExpOpts,
) -> Result<ExperimentResult> {
    let base = {
        let mut c = profile.base_config();
        c.technique = Technique::Start;
        c.dynamic_k = false; // fixed k for the sweep
        c
    };
    let seeds = [42u64, 43, 44];
    let mut cells = Vec::new();
    for &k in &[1.0, 1.25, 1.5, 1.75, 2.0] {
        for &seed in &seeds {
            let mut cfg = base.clone();
            cfg.k_straggler = k;
            cfg.seed = seed;
            cells.push(Cell { label: format!("k={k}|START|{seed}"), cfg });
        }
    }
    for &i in &[1usize, 2, 5] {
        for &seed in &seeds {
            let mut cfg = base.clone();
            cfg.predict_every = i;
            cfg.seed = seed;
            cells.push(Cell { label: format!("I={i}|START|{seed}"), cfg });
        }
    }
    for &t in &[1usize, 3, 5] {
        for &seed in &seeds {
            let mut cfg = base.clone();
            cfg.window_steps = t;
            cfg.seed = seed;
            cells.push(Cell { label: format!("T={t}|START|{seed}"), cfg });
        }
    }
    let results = execute("fig2", cells, threads, art_dir, opts)?;
    let grouped = group_results(&results, |m| m.confusion.f1());
    let mut tables = Vec::new();
    for (axis, points) in [
        ("k (straggler multiple)", vec!["k=1", "k=1.25", "k=1.5", "k=1.75", "k=2"]),
        ("I (inference period, intervals)", vec!["I=1", "I=2", "I=5"]),
        ("T (window length, steps)", vec!["T=1", "T=3", "T=5"]),
    ] {
        let mut t = Table::new(&format!("Fig.2 — F1 vs {axis}"), &["point", "F1"]);
        for p in points {
            if let Some(v) = grouped.get(p).and_then(|m| m.get("START")) {
                t.row(vec![p.to_string(), f1s(*v)]);
            }
        }
        tables.push(t);
    }
    Ok(ExperimentResult { id: "fig2", tables, raw: raw_map(&results) })
}

// ------------------------------------------------------------------ FIG 5

/// Fig. 5: response-time decomposition — prediction (START) nearly
/// eliminates the detection delay that reactive methods pay before
/// mitigating.  Reported: mean time-from-start-to-mitigation and mean
/// response of mitigated tasks.
pub fn fig5(
    profile: Profile,
    threads: usize,
    art_dir: &PathBuf,
    opts: &ExpOpts,
) -> Result<ExperimentResult> {
    let mut base = profile.base_config();
    base.fault_rate = 1.0;
    let techniques =
        [Technique::Start, Technique::IgruSd, Technique::Grass, Technique::NearestFit, Technique::Late];
    let seeds = [42u64, 43, 44];
    let mut cells = Vec::new();
    for &t in &techniques {
        for &seed in &seeds {
            let mut cfg = base.clone();
            cfg.technique = t;
            cfg.seed = seed;
            cells.push(Cell { label: format!("x|{}|{seed}", t.name()), cfg });
        }
    }
    let results = execute("fig5", cells, threads, art_dir, opts)?;
    let delay = group_results(&results, |m| {
        if m.mitigation_delays.is_empty() {
            0.0
        } else {
            Summary::of(&m.mitigation_delays).mean
        }
    });
    let resp = group_results(&results, |m| m.avg_execution_time());
    let mut table = Table::new(
        "Fig.5 — detection+mitigation delay (s) and response time (s)",
        &["technique", "time-to-mitigation", "avg response"],
    );
    for t in &techniques {
        let d = delay.get("x").and_then(|g| g.get(t.name())).copied().unwrap_or(f64::NAN);
        let r = resp.get("x").and_then(|g| g.get(t.name())).copied().unwrap_or(f64::NAN);
        table.row(vec![t.name().to_string(), secs(d), secs(r)]);
    }
    Ok(ExperimentResult { id: "fig5", tables: vec![table], raw: raw_map(&results) })
}

// ------------------------------------------------------------------ FIG 6

/// Fig. 6a–d: QoS vs reserved utilization (20/40/60/80 %).
pub fn fig6(
    profile: Profile,
    threads: usize,
    art_dir: &PathBuf,
    opts: &ExpOpts,
) -> Result<ExperimentResult> {
    let base = profile.base_config();
    let techniques = Technique::paper_set();
    let seeds = [42u64, 43, 44, 45, 46];
    let levels = profile.reserved_points();
    let sweep: Vec<(String, Box<dyn Fn(&mut SimConfig)>)> = levels
        .iter()
        .map(|&u| {
            let label = format!("{:.0}%", u * 100.0);
            let f: Box<dyn Fn(&mut SimConfig)> = Box::new(move |c: &mut SimConfig| {
                c.reserved_util = u;
            });
            (label, f)
        })
        .collect();
    let cells = technique_sweep_cells(&base, &techniques, &sweep, &seeds);
    let results = execute("fig6", cells, threads, art_dir, opts)?;
    let order: Vec<String> = levels.iter().map(|&u| format!("{:.0}%", u * 100.0)).collect();
    let tables = vec![
        sweep_table("Fig.6a — Execution time (s) vs reserved utilization", &order, &techniques,
            &group_results(&results, |m| m.avg_execution_time()), secs),
        sweep_table("Fig.6b — Resource contention vs reserved utilization", &order, &techniques,
            &group_results(&results, |m| m.avg_contention()), |v| format!("{v:.3}")),
        sweep_table("Fig.6c — Energy (kWh) vs reserved utilization", &order, &techniques,
            &group_results(&results, |m| m.total_energy_kwh()), kwh),
        sweep_table("Fig.6d — SLA violation rate vs reserved utilization", &order, &techniques,
            &group_results(&results, |m| m.sla_violation_rate()), pct),
    ];
    Ok(ExperimentResult { id: "fig6", tables, raw: raw_map(&results) })
}

// ------------------------------------------------------------------ FIG 7

/// Fig. 7a–h: QoS + utilizations vs number of workloads.
pub fn fig7(
    profile: Profile,
    threads: usize,
    art_dir: &PathBuf,
    opts: &ExpOpts,
) -> Result<ExperimentResult> {
    let base = profile.base_config();
    let techniques = Technique::paper_set();
    let seeds = [42u64, 43, 44, 45, 46];
    let points = profile.workload_points();
    let sweep: Vec<(String, Box<dyn Fn(&mut SimConfig)>)> = points
        .iter()
        .map(|&n| {
            let label = format!("{n}");
            let f: Box<dyn Fn(&mut SimConfig)> = Box::new(move |c: &mut SimConfig| {
                c.n_workloads = n;
            });
            (label, f)
        })
        .collect();
    let cells = technique_sweep_cells(&base, &techniques, &sweep, &seeds);
    let results = execute("fig7", cells, threads, art_dir, opts)?;
    let order: Vec<String> = points.iter().map(|n| format!("{n}")).collect();
    let tables = vec![
        sweep_table("Fig.7a — Execution time (s) vs workloads", &order, &techniques,
            &group_results(&results, |m| m.avg_execution_time()), secs),
        sweep_table("Fig.7b — Resource contention vs workloads", &order, &techniques,
            &group_results(&results, |m| m.avg_contention()), |v| format!("{v:.3}")),
        sweep_table("Fig.7c — Energy (kWh) vs workloads", &order, &techniques,
            &group_results(&results, |m| m.total_energy_kwh()), kwh),
        sweep_table("Fig.7d — SLA violation rate vs workloads", &order, &techniques,
            &group_results(&results, |m| m.sla_violation_rate()), pct),
        sweep_table("Fig.7e — Network utilization vs workloads", &order, &techniques,
            &group_results(&results, |m| m.avg_utils().3), pct),
        sweep_table("Fig.7f — CPU utilization vs workloads", &order, &techniques,
            &group_results(&results, |m| m.avg_utils().0), pct),
        sweep_table("Fig.7g — Disk utilization vs workloads", &order, &techniques,
            &group_results(&results, |m| m.avg_utils().2), pct),
        sweep_table("Fig.7h — Memory utilization vs workloads", &order, &techniques,
            &group_results(&results, |m| m.avg_utils().1), pct),
    ];
    Ok(ExperimentResult { id: "fig7", tables, raw: raw_map(&results) })
}

// ------------------------------------------------------------------ FIG 8

/// Fig. 8a–d: completion-time spread per reserved-utilization level.
pub fn fig8(
    profile: Profile,
    threads: usize,
    art_dir: &PathBuf,
    opts: &ExpOpts,
) -> Result<ExperimentResult> {
    let base = profile.base_config();
    let techniques = Technique::paper_set();
    let seeds = [42u64, 43, 44];
    let levels = profile.reserved_points();
    let sweep: Vec<(String, Box<dyn Fn(&mut SimConfig)>)> = levels
        .iter()
        .map(|&u| {
            let label = format!("{:.0}%", u * 100.0);
            let f: Box<dyn Fn(&mut SimConfig)> = Box::new(move |c: &mut SimConfig| {
                c.reserved_util = u;
            });
            (label, f)
        })
        .collect();
    let cells = technique_sweep_cells(&base, &techniques, &sweep, &seeds);
    let results = execute("fig8", cells, threads, art_dir, opts)?;
    let order: Vec<String> = levels.iter().map(|&u| format!("{:.0}%", u * 100.0)).collect();
    let tables = vec![
        sweep_table("Fig.8 — completion-time std (s): straggler spread", &order, &techniques,
            &group_results(&results, |m| m.exec_summary().std), secs),
        sweep_table("Fig.8 — completion-time p95 (s)", &order, &techniques,
            &group_results(&results, |m| m.exec_summary().p95), secs),
        sweep_table("Fig.8 — completion-time mean (s)", &order, &techniques,
            &group_results(&results, |m| m.exec_summary().mean), secs),
    ];
    Ok(ExperimentResult { id: "fig8", tables, raw: raw_map(&results) })
}

// ------------------------------------------------------------------ FIG 9

/// Fig. 9: prediction accuracy (MAPE) of START vs IGRU-SD vs RPPS as host
/// heterogeneity churns (number of Xeon-hosted VMs out of 200 varies,
/// with VM/host failures injected).
pub fn fig9(
    profile: Profile,
    threads: usize,
    art_dir: &PathBuf,
    opts: &ExpOpts,
) -> Result<ExperimentResult> {
    let mut base = profile.base_config();
    base.fault_rate = 1.5; // the paper's "injected VM failures"
    let techniques = [Technique::Start, Technique::IgruSd, Technique::Rpps];
    let seeds = [42u64, 43, 44];
    // 200 VMs split between i5 (6 VMs/PM) and Xeon (2 VMs/PM) hosts.
    let xeon_vm_counts = [20usize, 50, 80, 110, 140];
    let mut cells = Vec::new();
    for &xeon_vms in &xeon_vm_counts {
        let i5_vms = 200 - xeon_vms;
        let i5_pms = i5_vms / 6;
        let xeon_pms = xeon_vms / 2;
        for &t in &techniques {
            for &seed in &seeds {
                let mut cfg = base.clone();
                cfg.pm_counts = vec![0, i5_pms, xeon_pms];
                cfg.technique = t;
                cfg.seed = seed;
                cells.push(Cell { label: format!("{xeon_vms}|{}|{seed}", t.name()), cfg });
            }
        }
    }
    let results = execute("fig9", cells, threads, art_dir, opts)?;
    let grouped = group_results(&results, |m| m.straggler_mape());
    let order: Vec<String> = xeon_vm_counts.iter().map(|n| format!("{n}")).collect();
    let mut table = Table::new(
        "Fig.9 — straggler-count MAPE (%) vs #Xeon-hosted VMs (of 200)",
        &["xeon VMs", "START", "IGRU-SD", "RPPS"],
    );
    let empty = BTreeMap::new();
    for s in &order {
        let row = grouped.get(s).unwrap_or(&empty);
        table.row(vec![
            s.clone(),
            format!("{:.1}", row.get("START").copied().unwrap_or(f64::NAN)),
            format!("{:.1}", row.get("IGRU-SD").copied().unwrap_or(f64::NAN)),
            format!("{:.1}", row.get("RPPS").copied().unwrap_or(f64::NAN)),
        ]);
    }
    Ok(ExperimentResult { id: "fig9", tables: vec![table], raw: raw_map(&results) })
}

// ----------------------------------------------------------------- FIG 10

/// Fig. 10: manager overhead amortized over total task execution time.
pub fn fig10(
    profile: Profile,
    threads: usize,
    art_dir: &PathBuf,
    opts: &ExpOpts,
) -> Result<ExperimentResult> {
    let base = profile.base_config();
    let mut techniques = Technique::paper_set();
    techniques.push(Technique::Late);
    let seeds = [42u64, 43, 44];
    let mut cells = Vec::new();
    for &t in &techniques {
        for &seed in &seeds {
            let mut cfg = base.clone();
            cfg.technique = t;
            cfg.seed = seed;
            cells.push(Cell { label: format!("x|{}|{seed}", t.name()), cfg });
        }
    }
    let results = execute("fig10", cells, threads, art_dir, opts)?;
    // One shared definition of overhead: the profiler's predict+mitigate
    // counters (RunMetrics::manager_overhead_s), split out per phase in
    // the two rightmost columns so the figure shows where the time goes.
    let overhead = group_results(&results, |m| {
        let total_exec: f64 = m.exec_times.iter().sum();
        if total_exec > 0.0 {
            100.0 * m.manager_overhead_s() / total_exec
        } else {
            0.0
        }
    });
    let wall = group_results(&results, |m| m.manager_overhead_s());
    let predict = group_results(&results, |m| m.profile.seconds(crate::sim::trace::Phase::Predict));
    let mitigate =
        group_results(&results, |m| m.profile.seconds(crate::sim::trace::Phase::Mitigate));
    let mut table = Table::new(
        "Fig.10 — manager overhead (% of total task exec time; wall s)",
        &["technique", "overhead %", "wall s", "predict s", "mitigate s"],
    );
    for t in &techniques {
        table.row(vec![
            t.name().to_string(),
            format!("{:.4}", overhead["x"].get(t.name()).copied().unwrap_or(f64::NAN)),
            format!("{:.3}", wall["x"].get(t.name()).copied().unwrap_or(f64::NAN)),
            format!("{:.3}", predict["x"].get(t.name()).copied().unwrap_or(f64::NAN)),
            format!("{:.3}", mitigate["x"].get(t.name()).copied().unwrap_or(f64::NAN)),
        ]);
    }
    Ok(ExperimentResult { id: "fig10", tables: vec![table], raw: raw_map(&results) })
}

// --------------------------------------------------------------- HEADLINE

/// §1 headline: START vs best baseline on the four QoS metrics.
pub fn headline(
    profile: Profile,
    threads: usize,
    art_dir: &PathBuf,
    opts: &ExpOpts,
) -> Result<ExperimentResult> {
    let base = profile.base_config();
    let techniques = Technique::paper_set();
    let seeds = [42u64, 43, 44, 45, 46];
    let mut cells = Vec::new();
    for &t in &techniques {
        for &seed in &seeds {
            let mut cfg = base.clone();
            cfg.technique = t;
            cfg.seed = seed;
            cells.push(Cell { label: format!("x|{}|{seed}", t.name()), cfg });
        }
    }
    let results = execute("headline", cells, threads, art_dir, opts)?;
    let metrics: Vec<(&str, Box<dyn Fn(&RunMetrics) -> f64>, bool)> = vec![
        ("exec time (s)", Box::new(|m: &RunMetrics| m.avg_execution_time()), true),
        ("contention", Box::new(|m: &RunMetrics| m.avg_contention()), true),
        ("energy (kWh)", Box::new(|m: &RunMetrics| m.total_energy_kwh()), true),
        ("SLA violation", Box::new(|m: &RunMetrics| m.sla_violation_rate()), true),
    ];
    let mut table = Table::new(
        "Headline — START vs best baseline (paper: −13% exec, −11% cont, −16% energy, −19% SLA)",
        &["metric", "START", "best baseline", "who", "delta"],
    );
    let empty = BTreeMap::new();
    for (name, f, lower_better) in &metrics {
        let grouped = group_results(&results, f);
        // Under `--keep-going` the grid may be partial: missing entries
        // render as n/a instead of panicking the whole report.
        let row = grouped.get("x").unwrap_or(&empty);
        let start = row.get("START").copied().unwrap_or(f64::NAN);
        let best_baseline = row
            .iter()
            .filter(|(k, v)| k.as_str() != "START" && v.is_finite())
            .min_by(|a, b| {
                let ord = a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal);
                if *lower_better {
                    ord
                } else {
                    ord.reverse()
                }
            })
            .map(|(k, v)| (k.clone(), *v));
        let Some((who, best)) = best_baseline else {
            table.row(vec![name.to_string(), format!("{start:.3}"), "n/a".into(), "n/a".into(), "n/a".into()]);
            continue;
        };
        let delta = 100.0 * (start - best) / best.max(1e-12);
        table.row(vec![
            name.to_string(),
            format!("{start:.3}"),
            format!("{best:.3}"),
            who,
            format!("{delta:+.1}%"),
        ]);
    }
    Ok(ExperimentResult { id: "headline", tables: vec![table], raw: raw_map(&results) })
}
