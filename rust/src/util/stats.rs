//! Statistics helpers used by the metrics pipeline and experiment harness:
//! summary statistics, percentiles, EMA smoothing, MAPE (Eq. 14) and F1
//! (Eq. 5 as written in the paper).

/// Summary of a sample: count, mean, std, min/max, percentiles.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Summary {
        if samples.is_empty() {
            return Summary::default();
        }
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile_sorted(&sorted, 0.50),
            p95: percentile_sorted(&sorted, 0.95),
            p99: percentile_sorted(&sorted, 0.99),
        }
    }

    /// Population variance.
    pub fn variance(&self) -> f64 {
        self.std * self.std
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Percentile of an unsorted slice (copies).
pub fn percentile(samples: &[f64], q: f64) -> f64 {
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&sorted, q)
}

/// Exponential moving average with weight `w` on the latest observation
/// (paper §3.2 uses w = 0.8 on the resource matrices).
#[derive(Clone, Debug)]
pub struct Ema {
    weight: f64,
    value: Option<f64>,
}

impl Ema {
    pub fn new(weight: f64) -> Self {
        assert!((0.0..=1.0).contains(&weight));
        Self { weight, value: None }
    }

    pub fn push(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => self.weight * x + (1.0 - self.weight) * prev,
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

/// Streaming mean/variance (Welford).
#[derive(Clone, Debug, Default)]
pub struct Online {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Online {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn n(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Mean Absolute Percentage Error (Eq. 14), skipping intervals where the
/// actual value is zero (the paper's n is the number of scheduling
/// intervals).
pub fn mape(actual: &[f64], predicted: &[f64]) -> f64 {
    assert_eq!(actual.len(), predicted.len());
    let mut total = 0.0;
    let mut n = 0usize;
    for (&y, &yp) in actual.iter().zip(predicted) {
        if y.abs() > 1e-12 {
            total += ((y - yp) / y).abs();
            n += 1;
        }
    }
    if n == 0 {
        0.0
    } else {
        100.0 * total / n as f64
    }
}

/// Binary-classification counts for straggler prediction scoring.
#[derive(Clone, Copy, Debug, Default)]
pub struct Confusion {
    pub tp: u64,
    pub fp: u64,
    pub fn_: u64,
    pub tn: u64,
}

impl Confusion {
    pub fn record(&mut self, predicted: bool, actual: bool) {
        match (predicted, actual) {
            (true, true) => self.tp += 1,
            (true, false) => self.fp += 1,
            (false, true) => self.fn_ += 1,
            (false, false) => self.tn += 1,
        }
    }

    pub fn precision(&self) -> f64 {
        if self.tp + self.fp == 0 {
            0.0
        } else {
            self.tp as f64 / (self.tp + self.fp) as f64
        }
    }

    pub fn recall(&self) -> f64 {
        if self.tp + self.fn_ == 0 {
            0.0
        } else {
            self.tp as f64 / (self.tp + self.fn_) as f64
        }
    }

    /// Standard F1 = harmonic mean of precision and recall; this equals the
    /// paper's Eq. 5 form tp / (tp + (fp + fn)/2).
    pub fn f1(&self) -> f64 {
        let denom = self.tp as f64 + 0.5 * (self.fp + self.fn_) as f64;
        if denom == 0.0 {
            0.0
        } else {
            self.tp as f64 / denom
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((s.p50 - 2.5).abs() < 1e-12);
    }

    #[test]
    fn summary_empty() {
        assert_eq!(Summary::of(&[]), Summary::default());
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile(&xs, 0.5) - 5.0).abs() < 1e-12);
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 1.0), 10.0);
    }

    #[test]
    fn ema_first_value_passthrough_then_blends() {
        let mut e = Ema::new(0.8);
        assert_eq!(e.push(10.0), 10.0);
        let v = e.push(0.0);
        assert!((v - 2.0).abs() < 1e-12); // 0.8*0 + 0.2*10
    }

    #[test]
    fn online_matches_batch() {
        let xs = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0];
        let mut o = Online::default();
        for &x in &xs {
            o.push(x);
        }
        let s = Summary::of(&xs);
        assert!((o.mean() - s.mean).abs() < 1e-12);
        assert!((o.variance() - s.variance()).abs() < 1e-9);
    }

    #[test]
    fn mape_basic_and_zero_skip() {
        assert!((mape(&[10.0, 20.0], &[9.0, 22.0]) - 10.0).abs() < 1e-9);
        // zero actuals skipped
        assert!((mape(&[0.0, 10.0], &[5.0, 11.0]) - 10.0).abs() < 1e-9);
        assert_eq!(mape(&[0.0], &[1.0]), 0.0);
    }

    #[test]
    fn f1_perfect_and_degenerate() {
        let mut c = Confusion::default();
        c.record(true, true);
        c.record(false, false);
        assert_eq!(c.f1(), 1.0);
        let empty = Confusion::default();
        assert_eq!(empty.f1(), 0.0);
    }

    #[test]
    fn f1_equals_harmonic_mean() {
        let c = Confusion { tp: 6, fp: 2, fn_: 4, tn: 10 };
        let p = c.precision();
        let r = c.recall();
        let harm = 2.0 * p * r / (p + r);
        assert!((c.f1() - harm).abs() < 1e-12);
    }
}
