//! Property-testing harness (proptest is unavailable offline).
//!
//! `check(name, cases, |rng| ...)` runs a closure over many seeded RNGs;
//! on panic or `Err`, it reports the failing case seed so the case can be
//! replayed deterministically with `replay(seed, f)`.  No shrinking — our
//! generators take the RNG directly, so failures are already replayable
//! and usually small.

use crate::util::rng::Pcg;
use std::panic::{catch_unwind, AssertUnwindSafe};

/// Run `f` over `cases` deterministic cases; panics with the failing seed.
pub fn check<F>(name: &str, cases: u64, mut f: F)
where
    F: FnMut(&mut Pcg) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Pcg::new(seed, case);
        let result = catch_unwind(AssertUnwindSafe(|| f(&mut rng)));
        match result {
            Ok(Ok(())) => {}
            Ok(Err(msg)) => panic!(
                "property {name:?} failed on case {case} (seed {seed:#x}): {msg}"
            ),
            Err(panic) => {
                let msg = panic
                    .downcast_ref::<&str>()
                    .map(|s| s.to_string())
                    .or_else(|| panic.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "<non-string panic>".into());
                panic!("property {name:?} panicked on case {case} (seed {seed:#x}): {msg}");
            }
        }
    }
}

/// Replay one case by seed (use with the seed printed by `check`).
pub fn replay<F>(seed: u64, case: u64, mut f: F)
where
    F: FnMut(&mut Pcg) -> Result<(), String>,
{
    let mut rng = Pcg::new(seed, case);
    f(&mut rng).expect("replayed property failed");
}

/// Generator helpers for common simulator inputs.
pub mod gen {
    use crate::util::rng::Pcg;

    /// Vector of f64 in [lo, hi) with random length in [min_len, max_len].
    pub fn vec_f64(rng: &mut Pcg, min_len: usize, max_len: usize, lo: f64, hi: f64) -> Vec<f64> {
        let len = min_len + rng.below(max_len - min_len + 1);
        (0..len).map(|_| rng.range(lo, hi)).collect()
    }

    /// Vector of positive Pareto samples.
    pub fn pareto_samples(rng: &mut Pcg, n: usize, alpha: f64, beta: f64) -> Vec<f64> {
        (0..n).map(|_| rng.pareto(alpha, beta)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("sum-commutes", 50, |rng| {
            let a = rng.f64();
            let b = rng.f64();
            if (a + b - (b + a)).abs() < 1e-15 {
                Ok(())
            } else {
                Err("addition not commutative".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "always-fails")]
    fn failing_property_reports_seed() {
        check("always-fails", 3, |_| Err("nope".into()));
    }

    #[test]
    #[should_panic(expected = "panicked")]
    fn panicking_property_is_caught() {
        check("panics", 2, |_| panic!("boom"));
    }

    #[test]
    fn cases_are_deterministic() {
        let mut first: Vec<u64> = Vec::new();
        check("record", 5, |rng| {
            first.push(rng.next_u64());
            Ok(())
        });
        let mut second: Vec<u64> = Vec::new();
        check("record", 5, |rng| {
            second.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(first, second);
    }
}
