//! Self-contained substrate utilities.
//!
//! This image has no network access and only the `xla`/`anyhow` crates are
//! vendored, so the usual ecosystem pieces (rand, serde, clap, criterion,
//! proptest) are implemented here from scratch — see DESIGN.md §6.

pub mod cli;
pub mod json;
pub mod ptest;
pub mod rng;
pub mod stats;
