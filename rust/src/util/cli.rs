//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Grammar: `prog <subcommand> [--key value]... [--flag]... [positional]...`
//! Values may also be attached as `--key=value`.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
    /// (name, description) pairs registered by accessors, for --help.
    seen: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse_from<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut args = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if stripped.is_empty() {
                    bail!("bare '--' is not supported");
                }
                if let Some((k, v)) = stripped.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    args.options.insert(stripped.to_string(), v);
                } else {
                    args.flags.push(stripped.to_string());
                }
            } else if args.subcommand.is_none() && args.positional.is_empty() {
                args.subcommand = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    /// Parse the process command line.
    pub fn from_env() -> Result<Args> {
        Self::parse_from(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt_str(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    pub fn str_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt_str(name).unwrap_or(default)
    }

    pub fn opt_f64(&self, name: &str) -> Result<Option<f64>> {
        self.options
            .get(name)
            .map(|v| v.parse::<f64>().map_err(|e| anyhow!("--{name}={v:?}: {e}")))
            .transpose()
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        Ok(self.opt_f64(name)?.unwrap_or(default))
    }

    pub fn opt_usize(&self, name: &str) -> Result<Option<usize>> {
        self.options
            .get(name)
            .map(|v| v.parse::<usize>().map_err(|e| anyhow!("--{name}={v:?}: {e}")))
            .transpose()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        Ok(self.opt_usize(name)?.unwrap_or(default))
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        Ok(self
            .options
            .get(name)
            .map(|v| v.parse::<u64>().map_err(|e| anyhow!("--{name}={v:?}: {e}")))
            .transpose()?
            .unwrap_or(default))
    }

    pub fn opt_path(&self, name: &str) -> Option<std::path::PathBuf> {
        self.opt_str(name).map(std::path::PathBuf::from)
    }

    /// Record accessor usage (reserved for future --help generation).
    pub fn note(&mut self, name: &str) {
        self.seen.push(name.to_string());
    }

    /// Unknown-option check: everything the caller read should be listed.
    pub fn ensure_known(&self, known_opts: &[&str], known_flags: &[&str]) -> Result<()> {
        for k in self.options.keys() {
            if !known_opts.contains(&k.as_str()) {
                bail!("unknown option --{k} (known: {})", known_opts.join(", "));
            }
        }
        for f in &self.flags {
            if !known_flags.contains(&f.as_str()) {
                bail!("unknown flag --{f} (known: {})", known_flags.join(", "));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("experiment fig6 --seed 42 --fast --out=results.json");
        assert_eq!(a.subcommand.as_deref(), Some("experiment"));
        assert_eq!(a.positional, vec!["fig6"]);
        assert_eq!(a.u64_or("seed", 0).unwrap(), 42);
        assert!(a.flag("fast"));
        assert_eq!(a.opt_str("out"), Some("results.json"));
    }

    #[test]
    fn defaults() {
        let a = parse("simulate");
        assert_eq!(a.f64_or("duration", 1.5).unwrap(), 1.5);
        assert_eq!(a.usize_or("vms", 400).unwrap(), 400);
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn numeric_errors_are_reported() {
        let a = parse("x --seed abc");
        assert!(a.u64_or("seed", 0).is_err());
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("run --fast --verbose");
        assert!(a.flag("fast") && a.flag("verbose"));
    }

    #[test]
    fn negative_number_as_value() {
        // A value starting with '-' but not '--' binds to the option.
        let a = parse("x --offset -3.5");
        assert_eq!(a.f64_or("offset", 0.0).unwrap(), -3.5);
    }

    #[test]
    fn path_options() {
        let a = parse("simulate --trace out/run.jsonl");
        assert_eq!(a.opt_path("trace"), Some(std::path::PathBuf::from("out/run.jsonl")));
        assert_eq!(a.opt_path("missing"), None);
    }

    #[test]
    fn ensure_known_rejects_typos() {
        let a = parse("x --sede 42");
        assert!(a.ensure_known(&["seed"], &[]).is_err());
        let b = parse("x --seed 42");
        assert!(b.ensure_known(&["seed"], &[]).is_ok());
    }
}
