//! Minimal JSON parser/serializer (serde is unavailable offline).
//!
//! Used for: the AOT `manifest.json` / `golden.json` contract with the
//! Python compile path, experiment configuration files in `configs/`, and
//! machine-readable results dumps from the experiment harness.
//!
//! Supports the full JSON grammar except `\u` surrogate pairs are passed
//! through unvalidated (adequate: all our payloads are ASCII).

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---------------------------------------------------------- accessors

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style path access.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Required-field helpers with useful error messages.
    pub fn req_f64(&self, key: &str) -> Result<f64> {
        self.get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("missing/non-numeric field {key:?}"))
    }

    pub fn req_usize(&self, key: &str) -> Result<usize> {
        Ok(self.req_f64(key)? as usize)
    }

    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("missing/non-string field {key:?}"))
    }

    pub fn req_arr(&self, key: &str) -> Result<&[Json]> {
        self.get(key)
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing/non-array field {key:?}"))
    }

    /// Flatten a numeric array (fails on non-numbers).
    pub fn as_f32_vec(&self) -> Result<Vec<f32>> {
        let arr = self.as_arr().ok_or_else(|| anyhow!("expected array"))?;
        arr.iter()
            .map(|v| v.as_f64().map(|f| f as f32).ok_or_else(|| anyhow!("non-numeric element")))
            .collect()
    }

    // -------------------------------------------------------- constructors

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    // --------------------------------------------------------- serializer

    /// Compact serialization.
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json> {
    let bytes = text.as_bytes();
    let mut p = Parser { bytes, pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != bytes.len() {
        bail!("trailing garbage at byte {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Result<u8> {
        let b = self.peek().ok_or_else(|| anyhow!("unexpected EOF at byte {}", self.pos))?;
        self.pos += 1;
        Ok(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        let got = self.bump()?;
        if got != b {
            bail!("expected {:?} got {:?} at byte {}", b as char, got as char, self.pos - 1);
        }
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek().ok_or_else(|| anyhow!("unexpected EOF"))? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected byte {:?} at {}", c as char, self.pos),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump()? {
                b'"' => return Ok(s),
                b'\\' => match self.bump()? {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'n' => s.push('\n'),
                    b't' => s.push('\t'),
                    b'r' => s.push('\r'),
                    b'b' => s.push('\u{8}'),
                    b'f' => s.push('\u{c}'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self.bump()?;
                            code = code * 16
                                + (d as char)
                                    .to_digit(16)
                                    .ok_or_else(|| anyhow!("bad \\u escape"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    c => bail!("bad escape \\{}", c as char),
                },
                c if c < 0x80 => s.push(c as char),
                c => {
                    // Reassemble UTF-8 multibyte sequence.
                    let len = if c >= 0xF0 {
                        4
                    } else if c >= 0xE0 {
                        3
                    } else {
                        2
                    };
                    let start = self.pos - 1;
                    for _ in 1..len {
                        self.bump()?;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| anyhow!("invalid UTF-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        Ok(Json::Num(s.parse::<f64>().map_err(|e| anyhow!("bad number {s:?}: {e}"))?))
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => return Ok(Json::Arr(items)),
                c => bail!("expected ',' or ']' got {:?}", c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => return Ok(Json::Obj(map)),
                c => bail!("expected ',' or '}}' got {:?}", c as char),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse(" -12.5e2 ").unwrap(), Json::Num(-1250.0));
        assert_eq!(parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.path(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.path(&["a"]).unwrap().as_arr().unwrap()[2].req_str("b").unwrap(),
            "x"
        );
        assert_eq!(v.get("c"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,true,null,"s"],"num":3,"obj":{"k":"v"}}"#;
        let v = parse(src).unwrap();
        let dumped = v.dump();
        assert_eq!(parse(&dumped).unwrap(), v);
    }

    #[test]
    fn string_escapes_roundtrip() {
        let v = Json::Str("quote\" back\\ nl\n tab\t".into());
        assert_eq!(parse(&v.dump()).unwrap(), v);
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse(r#""αβ A""#).unwrap();
        assert_eq!(v, Json::Str("αβ A".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("{'a':1}").is_err());
    }

    #[test]
    fn f32_vec() {
        let v = parse("[1, 2.5, -3]").unwrap();
        assert_eq!(v.as_f32_vec().unwrap(), vec![1.0, 2.5, -3.0]);
        assert!(parse(r#"[1, "x"]"#).unwrap().as_f32_vec().is_err());
    }

    #[test]
    fn large_numeric_array() {
        let xs: Vec<f64> = (0..10_000).map(|i| i as f64 * 0.5).collect();
        let text = Json::arr_f64(&xs).dump();
        let back = parse(&text).unwrap();
        assert_eq!(back.as_arr().unwrap().len(), 10_000);
        assert_eq!(back.as_arr().unwrap()[9999].as_f64().unwrap(), 4999.5);
    }
}
