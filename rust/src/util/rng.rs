//! Deterministic PRNG + statistical distributions (rand/rand_distr are not
//! available offline; the simulator needs Weibull/Pareto/Poisson anyway).
//!
//! Core generator is PCG-XSH-RR-64/32 seeded through SplitMix64 — small,
//! fast, and with independent streams so every simulator subsystem (fault
//! injector, workload generator, scheduler) can own a decorrelated RNG and
//! experiments stay reproducible under module reordering.

/// SplitMix64: used for seeding and cheap stateless hashing.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// PCG-XSH-RR 64/32 with stream selection.
#[derive(Clone, Debug)]
pub struct Pcg {
    state: u64,
    inc: u64,
}

impl Pcg {
    /// Seed a generator; `stream` decorrelates subsystem RNGs.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut sm = seed;
        let init = splitmix64(&mut sm);
        let mut rng = Self { state: 0, inc: (stream << 1) | 1 };
        rng.state = rng.inc.wrapping_add(init);
        rng.next_u32();
        rng
    }

    /// Convenience: stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Derive a child RNG for a named subsystem (stable across runs).
    pub fn fork(&mut self, tag: u64) -> Pcg {
        let seed = (self.next_u64()).wrapping_add(tag.wrapping_mul(0x9E3779B97F4A7C15));
        Pcg::new(seed, tag)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(6364136223846793005).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n) (Lemire-style rejection-free for our use).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.f64() * n as f64) as usize % n
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn int_range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi >= lo);
        lo + self.below((hi - lo + 1) as usize) as i64
    }

    /// Bernoulli(p).
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (polar form).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = 2.0 * self.f64() - 1.0;
            let v = 2.0 * self.f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    /// Normal(mu, sigma).
    #[inline]
    pub fn normal_ms(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.normal()
    }

    /// Lognormal with underlying Normal(mu, sigma).
    #[inline]
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal_ms(mu, sigma).exp()
    }

    /// Exponential with rate `lambda` (mean 1/λ).
    #[inline]
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        -(1.0 - self.f64()).ln() / lambda
    }

    /// Poisson(λ): Knuth for λ < 30, normal approximation above.
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        debug_assert!(lambda >= 0.0);
        if lambda <= 0.0 {
            return 0;
        }
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            let x = self.normal_ms(lambda, lambda.sqrt()).round();
            if x < 0.0 {
                0
            } else {
                x as u64
            }
        }
    }

    /// Weibull(shape k, scale λ) via inverse CDF — the paper's failure
    /// model (Eq. 15) uses k = 1.5, λ = 2.
    #[inline]
    pub fn weibull(&mut self, shape: f64, scale: f64) -> f64 {
        debug_assert!(shape > 0.0 && scale > 0.0);
        scale * (-(1.0 - self.f64()).ln()).powf(1.0 / shape)
    }

    /// Pareto(α, β): X = β·U^(−1/α), X ≥ β — the paper's task-time model
    /// (Eq. 1).
    #[inline]
    pub fn pareto(&mut self, alpha: f64, beta: f64) -> f64 {
        debug_assert!(alpha > 0.0 && beta > 0.0);
        let u = (1.0 - self.f64()).max(1e-12);
        beta * u.powf(-1.0 / alpha)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Choose a random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn moments(samples: &[f64]) -> (f64, f64) {
        let n = samples.len() as f64;
        let mean = samples.iter().sum::<f64>() / n;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
        (mean, var)
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Pcg::new(7, 1);
        let mut b = Pcg::new(7, 1);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_decorrelated() {
        let mut a = Pcg::new(7, 1);
        let mut b = Pcg::new(7, 2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same <= 2);
    }

    #[test]
    fn uniform_range_and_moments() {
        let mut rng = Pcg::seeded(1);
        let xs: Vec<f64> = (0..20000).map(|_| rng.f64()).collect();
        assert!(xs.iter().all(|&x| (0.0..1.0).contains(&x)));
        let (mean, var) = moments(&xs);
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
        assert!((var - 1.0 / 12.0).abs() < 0.01, "var {var}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg::seeded(2);
        let xs: Vec<f64> = (0..20000).map(|_| rng.normal_ms(3.0, 2.0)).collect();
        let (mean, var) = moments(&xs);
        assert!((mean - 3.0).abs() < 0.08, "mean {mean}");
        assert!((var - 4.0).abs() < 0.25, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut rng = Pcg::seeded(3);
        let xs: Vec<f64> = (0..20000).map(|_| rng.exponential(2.0)).collect();
        let (mean, _) = moments(&xs);
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn poisson_small_and_large_lambda() {
        let mut rng = Pcg::seeded(4);
        for &lambda in &[0.5, 1.2, 8.0, 50.0] {
            let xs: Vec<f64> = (0..20000).map(|_| rng.poisson(lambda) as f64).collect();
            let (mean, var) = moments(&xs);
            assert!((mean - lambda).abs() < 0.15 * lambda.max(1.0), "λ={lambda} mean {mean}");
            assert!((var - lambda).abs() < 0.25 * lambda.max(1.0), "λ={lambda} var {var}");
        }
    }

    #[test]
    fn weibull_mean_matches_gamma_formula() {
        // mean = λ·Γ(1 + 1/k); for k=1.5, λ=2: Γ(5/3) ≈ 0.902745, mean ≈ 1.80549.
        let mut rng = Pcg::seeded(5);
        let xs: Vec<f64> = (0..40000).map(|_| rng.weibull(1.5, 2.0)).collect();
        let (mean, _) = moments(&xs);
        assert!((mean - 1.80549).abs() < 0.03, "mean {mean}");
    }

    #[test]
    fn pareto_support_and_mean() {
        let mut rng = Pcg::seeded(6);
        let (alpha, beta) = (2.5, 1.5);
        let xs: Vec<f64> = (0..40000).map(|_| rng.pareto(alpha, beta)).collect();
        assert!(xs.iter().all(|&x| x >= beta));
        let (mean, _) = moments(&xs);
        let expect = alpha * beta / (alpha - 1.0); // 2.5
        assert!((mean - expect).abs() < 0.06, "mean {mean} expect {expect}");
    }

    #[test]
    fn pareto_tail_probability() {
        // P(X > K) = (K/β)^(−α) — this identity is Eq. 4's core.
        let mut rng = Pcg::seeded(7);
        let (alpha, beta, k) = (2.0, 1.0, 3.0);
        let n = 50000;
        let hits = (0..n).filter(|_| rng.pareto(alpha, beta) > k).count();
        let got = hits as f64 / n as f64;
        let want = (k / beta).powf(-alpha); // 1/9
        assert!((got - want).abs() < 0.01, "got {got} want {want}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg::seeded(8);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn int_range_inclusive_bounds() {
        let mut rng = Pcg::seeded(9);
        let mut saw_lo = false;
        let mut saw_hi = false;
        for _ in 0..2000 {
            let v = rng.int_range(2, 10);
            assert!((2..=10).contains(&v));
            saw_lo |= v == 2;
            saw_hi |= v == 10;
        }
        assert!(saw_lo && saw_hi);
    }
}
