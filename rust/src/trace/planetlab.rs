//! Synthetic PlanetLab-like host-utilization traces.
//!
//! The paper consumes CoMon traces from PlanetLab (1000+ tasks, 300 s
//! intervals, 2880 intervals per trace) which are not downloadable in this
//! offline environment.  This generator reproduces the stylized facts the
//! literature reports for those traces (heavy-tailed CPU load, diurnal
//! cycles, strong autocorrelation, occasional load spikes) and drives each
//! host's *background load* — the same role the real traces play in the
//! paper's CloudSim setup.  See DESIGN.md §5 (substitutions).

use crate::util::rng::Pcg;

/// Per-host background-utilization time series.
#[derive(Clone, Debug)]
pub struct PlanetLabTrace {
    /// Utilization in [0, 1] per interval.
    pub samples: Vec<f64>,
    pub interval_s: f64,
}

/// Generator parameters (defaults match the PlanetLab stylized facts).
#[derive(Clone, Copy, Debug)]
pub struct TraceParams {
    /// Number of 300 s intervals (PlanetLab: 2880 = 10 days? paper uses
    /// 288-interval runs; we generate what's asked).
    pub n_intervals: usize,
    pub interval_s: f64,
    /// Mean of the lognormal base load.
    pub base_mu: f64,
    pub base_sigma: f64,
    /// Diurnal amplitude (fraction of base).
    pub diurnal_amp: f64,
    /// AR(1) persistence and innovation scale.
    pub rho: f64,
    pub noise: f64,
    /// Probability per interval of a load spike, and its magnitude.
    pub spike_prob: f64,
    pub spike_mag: f64,
}

impl Default for TraceParams {
    fn default() -> Self {
        TraceParams {
            n_intervals: 288,
            interval_s: 300.0,
            base_mu: -1.9, // median load ≈ 15 %
            base_sigma: 0.6,
            diurnal_amp: 0.25,
            rho: 0.9,
            noise: 0.08,
            spike_prob: 0.02,
            spike_mag: 0.5,
        }
    }
}

impl PlanetLabTrace {
    /// Generate one host's trace.
    pub fn generate(params: &TraceParams, rng: &mut Pcg) -> PlanetLabTrace {
        let base = rng.lognormal(params.base_mu, params.base_sigma).min(0.6);
        let phase = rng.range(0.0, std::f64::consts::TAU);
        let day = 86_400.0 / params.interval_s; // intervals per day
        let mut ar = 0.0f64;
        let mut samples = Vec::with_capacity(params.n_intervals);
        for i in 0..params.n_intervals {
            ar = params.rho * ar + rng.normal_ms(0.0, params.noise);
            let diurnal =
                params.diurnal_amp * (std::f64::consts::TAU * i as f64 / day + phase).sin();
            let spike = if rng.chance(params.spike_prob) {
                rng.range(0.2, params.spike_mag + 0.2)
            } else {
                0.0
            };
            // Cap at 75 %: CoMon hosts rarely pin above this for whole
            // 5-minute intervals, and an (almost-)starved host would make
            // unmitigated runs unboundedly long.
            let u = (base * (1.0 + diurnal) + ar + spike).clamp(0.0, 0.75);
            samples.push(u);
        }
        PlanetLabTrace { samples, interval_s: params.interval_s }
    }

    /// Utilization at an interval index (clamps past the end).
    pub fn at(&self, interval: usize) -> f64 {
        match self.samples.get(interval) {
            Some(&u) => u,
            None => *self.samples.last().unwrap_or(&0.0),
        }
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::Summary;

    fn traces(n: usize) -> Vec<PlanetLabTrace> {
        let mut rng = Pcg::seeded(11);
        let p = TraceParams::default();
        (0..n).map(|_| PlanetLabTrace::generate(&p, &mut rng)).collect()
    }

    #[test]
    fn bounds_and_length() {
        for t in traces(50) {
            assert_eq!(t.len(), 288);
            assert!(t.samples.iter().all(|&u| (0.0..=0.75).contains(&u)));
        }
    }

    #[test]
    fn autocorrelated() {
        // lag-1 autocorrelation should be clearly positive (PlanetLab fact).
        let ts = traces(30);
        let mut acs = Vec::new();
        for t in &ts {
            let s = Summary::of(&t.samples);
            if s.std < 1e-6 {
                continue;
            }
            let mean = s.mean;
            let num: f64 = t
                .samples
                .windows(2)
                .map(|w| (w[0] - mean) * (w[1] - mean))
                .sum();
            let den: f64 = t.samples.iter().map(|x| (x - mean) * (x - mean)).sum();
            acs.push(num / den);
        }
        let mean_ac = acs.iter().sum::<f64>() / acs.len() as f64;
        assert!(mean_ac > 0.5, "lag-1 autocorr {mean_ac}");
    }

    #[test]
    fn heterogeneous_base_loads() {
        // Host medians should spread (lognormal base): heavy-tailed fleet.
        let ts = traces(100);
        let medians: Vec<f64> = ts.iter().map(|t| Summary::of(&t.samples).p50).collect();
        let s = Summary::of(&medians);
        assert!(s.std > 0.05, "median spread {}", s.std);
        assert!(s.max > 2.0 * s.p50, "no heavy tail: max {} p50 {}", s.max, s.p50);
    }

    #[test]
    fn spikes_occur() {
        let ts = traces(50);
        let spiky = ts
            .iter()
            .filter(|t| {
                let s = Summary::of(&t.samples);
                s.max > s.p50 + 0.2
            })
            .count();
        assert!(spiky > 10, "only {spiky} spiky traces");
    }

    #[test]
    fn at_clamps() {
        let t = traces(1).pop().unwrap();
        assert_eq!(t.at(1_000_000), *t.samples.last().unwrap());
    }
}
