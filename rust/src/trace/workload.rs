//! Cloudlet/job workload generator (paper §4.2, Table 4).
//!
//! Jobs arrive per scheduling interval as Poisson(λ = 1.2); each job is a
//! bag of 2–10 tasks; 50 % of jobs are deadline-driven.  Task requirements
//! are drawn from the Table 4 ranges: workload size 10000 ± 3000 MB
//! (mapped to MI), input/output file sizes 300 ± 120/150 MB (mapped to
//! disk/bandwidth demand), memory 2–12 GB scaled to VM-sized slices.

use crate::util::rng::Pcg;

/// Specification of one task (cloudlet) before materialization.
#[derive(Clone, Debug)]
pub struct TaskSpec {
    pub length_mi: f64,
    pub mips: f64,
    pub ram_gb: f64,
    pub disk_gb: f64,
    pub bw_kbps: f64,
}

/// Specification of one bag-of-tasks job.
#[derive(Clone, Debug)]
pub struct JobSpec {
    pub tasks: Vec<TaskSpec>,
    pub deadline_driven: bool,
    /// SLA weight w_i (Eq. 13).
    pub sla_weight: f64,
}

/// Stateful generator: one instance per simulation run.
pub struct WorkloadGenerator {
    rng: Pcg,
    lambda: f64,
    tasks_per_job: (usize, usize),
    deadline_fraction: f64,
    /// Stop after this many tasks (Table 4: 5000 cloudlets).
    budget: usize,
    generated: usize,
}

impl WorkloadGenerator {
    pub fn new(
        rng: Pcg,
        lambda: f64,
        tasks_per_job: (usize, usize),
        deadline_fraction: f64,
        budget: usize,
    ) -> Self {
        Self { rng, lambda, tasks_per_job, deadline_fraction, budget, generated: 0 }
    }

    /// Remaining cloudlet budget.
    pub fn remaining(&self) -> usize {
        self.budget.saturating_sub(self.generated)
    }

    /// Total cloudlets generated so far.
    pub fn generated(&self) -> usize {
        self.generated
    }

    /// Draw the jobs arriving in one scheduling interval.
    pub fn arrivals(&mut self) -> Vec<JobSpec> {
        let n_jobs = self.rng.poisson(self.lambda) as usize;
        let mut jobs = Vec::with_capacity(n_jobs);
        for _ in 0..n_jobs {
            if self.remaining() == 0 {
                break;
            }
            jobs.push(self.one_job());
        }
        jobs
    }

    /// Generate a single job (clamped to the remaining cloudlet budget).
    pub fn one_job(&mut self) -> JobSpec {
        let (lo, hi) = self.tasks_per_job;
        let mut q = self.rng.int_range(lo as i64, hi as i64) as usize;
        q = q.min(self.remaining()).max(1);
        let tasks = (0..q).map(|_| self.one_task()).collect();
        self.generated += q;
        JobSpec {
            tasks,
            deadline_driven: self.rng.chance(self.deadline_fraction),
            sla_weight: self.rng.range(0.5, 1.5),
        }
    }

    /// One task from Table 4 ranges.
    fn one_task(&mut self) -> TaskSpec {
        // Workload size 10000 ± 3000 MB → MI via CPU IPS 2000 M.
        let size_mb = self.rng.normal_ms(10_000.0, 3_000.0).clamp(1_000.0, 19_000.0);
        // ~50 MI per MB ⇒ nominal duration ≈ 40–60 min on a fair VM share.
        // Calibrated so the Table 4 workload (5000 cloudlets / 400 VMs /
        // 24 h) drives the fleet to ~65 % CPU utilization — the
        // resource-constrained regime the paper's straggler story assumes
        // (§1: contention is the main cause of stragglers).
        let length_mi = size_mb * 50.0;
        // CPU demand: a slice of a VM (Table 4 CPU IPS 2000M across VMs).
        let mips = self.rng.range(80.0, 400.0);
        // Memory 2–12 GB for hosts; per-task slices scaled to VM shares.
        let ram_gb = self.rng.range(0.1, 0.5);
        // Input + output file sizes 300 ± 120/150 MB → disk footprint (GB).
        let input_mb = self.rng.normal_ms(300.0, 120.0).clamp(30.0, 800.0);
        let output_mb = self.rng.normal_ms(300.0, 150.0).clamp(30.0, 900.0);
        let disk_gb = (input_mb + output_mb) / 1024.0;
        // Host bandwidth 1–2 KB/s total; tasks demand a share.
        let bw_kbps = self.rng.range(0.05, 0.4);
        TaskSpec { length_mi, mips, ram_gb, disk_gb, bw_kbps }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ptest;

    fn generator(budget: usize) -> WorkloadGenerator {
        WorkloadGenerator::new(Pcg::seeded(3), 1.2, (2, 10), 0.5, budget)
    }

    #[test]
    fn arrivals_follow_poisson_mean() {
        let mut g = generator(1_000_000);
        let n: usize = (0..5000).map(|_| g.arrivals().len()).sum();
        let mean = n as f64 / 5000.0;
        assert!((mean - 1.2).abs() < 0.1, "mean arrivals {mean}");
    }

    #[test]
    fn task_counts_in_range() {
        let mut g = generator(1_000_000);
        for _ in 0..500 {
            let j = g.one_job();
            assert!((2..=10).contains(&j.tasks.len()));
        }
    }

    #[test]
    fn budget_respected_exactly() {
        let mut g = generator(25);
        let mut total = 0;
        for _ in 0..100 {
            total += g.arrivals().iter().map(|j| j.tasks.len()).sum::<usize>();
        }
        assert_eq!(total, 25);
        assert_eq!(g.remaining(), 0);
    }

    #[test]
    fn deadline_fraction_about_half() {
        let mut g = generator(1_000_000);
        let jobs: Vec<_> = (0..2000).map(|_| g.one_job()).collect();
        let dd = jobs.iter().filter(|j| j.deadline_driven).count();
        let frac = dd as f64 / jobs.len() as f64;
        assert!((frac - 0.5).abs() < 0.05, "deadline fraction {frac}");
    }

    #[test]
    fn property_task_ranges() {
        ptest::check("task-spec-ranges", 20, |rng| {
            let mut g = WorkloadGenerator::new(rng.fork(1), 1.2, (2, 10), 0.5, 10_000);
            for _ in 0..50 {
                let j = g.one_job();
                for t in &j.tasks {
                    if !(t.length_mi > 0.0 && t.mips > 0.0 && t.ram_gb > 0.0) {
                        return Err(format!("non-positive demand {t:?}"));
                    }
                    if t.length_mi > 19_000.0 * 50.0 + 1.0 {
                        return Err(format!("length out of range {t:?}"));
                    }
                    if !(0.5..=1.5).contains(&j.sla_weight) {
                        return Err("sla weight out of range".into());
                    }
                }
            }
            Ok(())
        });
    }
}
