//! Workload substrate: synthetic PlanetLab-like utilization traces, the
//! Poisson job/cloudlet generator (Table 4 parameter ranges), and the Rust
//! mirror of the Python generative model (`python/compile/synth.py`).

pub mod generative;
pub mod planetlab;
pub mod workload;

pub use generative::Generative;
pub use planetlab::PlanetLabTrace;
pub use workload::{JobSpec, TaskSpec, WorkloadGenerator};
