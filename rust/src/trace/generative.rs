//! Rust mirror of `python/compile/synth.true_pareto_params` — the shared
//! generative contract between the training distribution and the simulator
//! (DESIGN.md §5).  Pinned bit-for-bit against Python by the golden test in
//! `rust/tests/runtime_golden.rs` (`generative` entry of golden.json).
//!
//! Column indices must match `python/compile/dims.py`.

use crate::runtime::GenerativeConstants;

/// M_H column indices (dims.py layout).
pub const H_CPU_UTIL: usize = 0;
pub const H_RAM_UTIL: usize = 1;
pub const H_DISK_UTIL: usize = 2;
pub const H_BW_UTIL: usize = 3;
pub const H_CPU_CAP: usize = 4;
pub const H_RAM_CAP: usize = 5;
pub const H_DISK_CAP: usize = 6;
pub const H_BW_CAP: usize = 7;
pub const H_POWER: usize = 8;
pub const H_COST: usize = 9;
pub const H_NTASKS: usize = 10;
pub const H_IS_UP: usize = 11;

/// M_T column indices (dims.py layout).
pub const T_CPU_REQ: usize = 0;
pub const T_RAM_REQ: usize = 1;
pub const T_DISK_REQ: usize = 2;
pub const T_BW_REQ: usize = 3;
pub const T_PREV_HOST: usize = 4;
pub const T_DEADLINE: usize = 5;
pub const T_PROGRESS: usize = 6;
pub const T_ACTIVE: usize = 7;

/// Ground-truth (α*, β*) evaluator.
#[derive(Clone, Copy, Debug)]
pub struct Generative {
    pub c: GenerativeConstants,
    pub m_feats: usize,
    pub p_feats: usize,
}

impl Generative {
    pub fn new(c: GenerativeConstants, m_feats: usize, p_feats: usize) -> Self {
        Self { c, m_feats, p_feats }
    }

    /// Compute (α*, β*) from flattened feature matrices, mirroring
    /// `synth.true_pareto_params` exactly (f32 inputs, f64 math — the
    /// Python side computes in f32; golden tolerance covers the gap).
    pub fn pareto_params(&self, m_h: &[f32], m_t: &[f32]) -> (f64, f64) {
        let g = &self.c;
        let m = self.m_feats;
        let p = self.p_feats;
        debug_assert_eq!(m_h.len() % m, 0);
        debug_assert_eq!(m_t.len() % p, 0);

        let n_hosts = m_h.len() / m;
        let mut n_up = 0.0f64;
        let mut u_sum = 0.0f64;
        let mut c_sum = 0.0f64;
        let mut cap_sum = 0.0f64;
        for i in 0..n_hosts {
            let row = &m_h[i * m..(i + 1) * m];
            let up = row[H_IS_UP] as f64;
            n_up += up;
            u_sum += row[H_CPU_UTIL] as f64 * up;
            let pressure = row[H_CPU_UTIL] as f64 + row[H_RAM_UTIL] as f64;
            c_sum += (pressure - g.contention_knee).max(0.0) * up;
            cap_sum += row[H_CPU_CAP] as f64 * up;
        }
        let n_up_c = n_up.max(1e-6);
        let u = u_sum / n_up_c;
        let contention = c_sum / n_up_c;
        let cap_mean = cap_sum / n_up_c;
        let mut cap_var = 0.0f64;
        for i in 0..n_hosts {
            let row = &m_h[i * m..(i + 1) * m];
            let up = row[H_IS_UP] as f64;
            let d = row[H_CPU_CAP] as f64 - cap_mean;
            cap_var += d * d * up;
        }
        let het = (cap_var / n_up_c).max(0.0).sqrt();

        let n_tasks = m_t.len() / p;
        let mut n_act = 0.0f64;
        let mut d_sum = 0.0f64;
        for i in 0..n_tasks {
            let row = &m_t[i * p..(i + 1) * p];
            let act = row[T_ACTIVE] as f64;
            n_act += act;
            d_sum += row[T_CPU_REQ] as f64 * act;
        }
        let d = d_sum / n_act.max(1e-6);

        let z = g.alpha_gain * (g.alpha_mid - u - g.contention_weight * contention - g.hetero_weight * het * u);
        let alpha = g.alpha_min + g.alpha_span / (1.0 + (-z).exp());
        let beta = g.beta_base * (g.beta_demand_lo + g.beta_demand_w * d) * (1.0 + g.beta_load_w * u);
        (alpha, beta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn consts() -> GenerativeConstants {
        GenerativeConstants {
            alpha_min: 1.15,
            alpha_span: 2.85,
            alpha_gain: 4.0,
            alpha_mid: 0.65,
            contention_weight: 0.5,
            hetero_weight: 0.4,
            beta_base: 1.0,
            beta_demand_lo: 0.4,
            beta_demand_w: 1.2,
            beta_load_w: 0.8,
            contention_knee: 1.2,
        }
    }

    fn flat_mh(n: usize, util: f32, cap: f32) -> Vec<f32> {
        let mut v = vec![0.0f32; n * 12];
        for i in 0..n {
            v[i * 12 + H_CPU_UTIL] = util;
            v[i * 12 + H_CPU_CAP] = cap;
            v[i * 12 + H_IS_UP] = 1.0;
        }
        v
    }

    fn flat_mt(q: usize, req: f32) -> Vec<f32> {
        let mut v = vec![0.0f32; q * 8];
        for i in 0..q {
            v[i * 8 + T_CPU_REQ] = req;
            v[i * 8 + T_ACTIVE] = 1.0;
        }
        v
    }

    #[test]
    fn alpha_in_range_and_monotone_in_load() {
        let g = Generative::new(consts(), 12, 8);
        let (a_lo, _) = g.pareto_params(&flat_mh(20, 0.1, 0.5), &flat_mt(5, 0.5));
        let (a_hi, _) = g.pareto_params(&flat_mh(20, 0.9, 0.5), &flat_mt(5, 0.5));
        assert!(a_lo > a_hi, "low load should have lighter tail: {a_lo} vs {a_hi}");
        assert!(a_lo <= 1.15 + 2.85 + 1e-9 && a_hi >= 1.15 - 1e-9);
    }

    #[test]
    fn beta_grows_with_demand_and_load() {
        let g = Generative::new(consts(), 12, 8);
        let (_, b1) = g.pareto_params(&flat_mh(20, 0.2, 0.5), &flat_mt(5, 0.2));
        let (_, b2) = g.pareto_params(&flat_mh(20, 0.2, 0.5), &flat_mt(5, 0.8));
        let (_, b3) = g.pareto_params(&flat_mh(20, 0.8, 0.5), &flat_mt(5, 0.8));
        assert!(b2 > b1 && b3 > b2, "{b1} {b2} {b3}");
    }

    #[test]
    fn heterogeneity_lowers_alpha_under_load() {
        let g = Generative::new(consts(), 12, 8);
        let homo = flat_mh(20, 0.7, 0.5);
        let mut hetero = flat_mh(20, 0.7, 0.5);
        for i in 0..20 {
            hetero[i * 12 + H_CPU_CAP] = if i % 2 == 0 { 0.15 } else { 0.95 };
        }
        let (a_homo, _) = g.pareto_params(&homo, &flat_mt(5, 0.5));
        let (a_het, _) = g.pareto_params(&hetero, &flat_mt(5, 0.5));
        assert!(a_het < a_homo, "{a_het} vs {a_homo}");
    }

    #[test]
    fn down_hosts_excluded() {
        let g = Generative::new(consts(), 12, 8);
        let mut m_h = flat_mh(20, 0.2, 0.5);
        // Take half the hosts down with huge "util" — must be ignored.
        for i in 0..10 {
            m_h[i * 12 + H_CPU_UTIL] = 1.0;
            m_h[i * 12 + H_IS_UP] = 0.0;
        }
        let (a, _) = g.pareto_params(&m_h, &flat_mt(5, 0.5));
        let (a_ref, _) = g.pareto_params(&flat_mh(10, 0.2, 0.5), &flat_mt(5, 0.5));
        assert!((a - a_ref).abs() < 1e-9);
    }
}
