//! PJRT runtime: load AOT HLO-text artifacts and execute them on the CPU
//! client from the Rust hot path.
//!
//! Python/JAX runs only at build time (`make artifacts`); this module is the
//! entire model-execution surface of the running system.  One compiled
//! executable per model variant, cached for the process lifetime.
//!
//! Interchange is HLO **text** (see `python/compile/aot.py`): the `xla`
//! crate's text parser reassigns instruction ids, avoiding the 64-bit-id
//! protos that xla_extension 0.5.1 rejects.
//!
//! The `xla` native dependency is gated behind the `pjrt` cargo feature so
//! the simulator, baselines and experiment harness build and test on
//! machines without the XLA toolchain; without the feature, model loading
//! fails with a clear error and model-driven techniques are unavailable
//! (DESIGN.md §8).

mod manifest;
mod model;

pub use manifest::{GenerativeConstants, Manifest};
pub use model::{IgruModel, LstmState, StartModel};

#[cfg(feature = "pjrt")]
mod pjrt_backend {
    use anyhow::{Context, Result};
    use std::path::{Path, PathBuf};

    /// A compiled HLO executable plus the client it runs on.
    pub struct Executable {
        exe: xla::PjRtLoadedExecutable,
        name: String,
    }

    /// Shared PJRT CPU client; compile-once cache of executables.
    pub struct PjrtRuntime {
        client: xla::PjRtClient,
        art_dir: PathBuf,
    }

    impl PjrtRuntime {
        /// Create a CPU PJRT client rooted at an artifact directory.
        pub fn new(art_dir: impl AsRef<Path>) -> Result<Self> {
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(Self { client, art_dir: art_dir.as_ref().to_path_buf() })
        }

        /// The artifact directory this runtime loads from.
        pub fn artifact_dir(&self) -> &Path {
            &self.art_dir
        }

        /// PJRT platform name (diagnostics).
        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load + compile an HLO-text artifact by file name.
        pub fn load(&self, file_name: &str) -> Result<Executable> {
            let path = self.art_dir.join(file_name);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))?;
            Ok(Executable { exe, name: file_name.to_string() })
        }
    }

    impl Executable {
        /// Execute with f32 buffers; returns each output flattened to `Vec<f32>`.
        ///
        /// All our artifacts are lowered with `return_tuple=True`, so the single
        /// result literal is a tuple which we decompose.
        pub fn run_f32(&self, inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
            let mut lits = Vec::with_capacity(inputs.len());
            for (data, shape) in inputs {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                let lit = xla::Literal::vec1(data)
                    .reshape(&dims)
                    .with_context(|| format!("reshaping input for {}", self.name))?;
                lits.push(lit);
            }
            let result = self
                .exe
                .execute::<xla::Literal>(&lits)
                .with_context(|| format!("executing {}", self.name))?[0][0]
                .to_literal_sync()?;
            let parts = result.to_tuple()?;
            let mut out = Vec::with_capacity(parts.len());
            for p in parts {
                out.push(p.to_vec::<f32>()?);
            }
            Ok(out)
        }

        /// Artifact file name this executable was compiled from.
        pub fn name(&self) -> &str {
            &self.name
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_backend::{Executable, PjrtRuntime};

#[cfg(not(feature = "pjrt"))]
mod stub_backend {
    use anyhow::{bail, Result};
    use std::path::Path;

    /// Uninhabited executable handle: without the `pjrt` feature a runtime
    /// can never be constructed, so no executable can exist either.
    pub struct Executable {
        never: std::convert::Infallible,
    }

    impl Executable {
        pub fn run_f32(&self, _inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
            match self.never {}
        }

        pub fn name(&self) -> &str {
            match self.never {}
        }
    }

    /// Stub runtime: construction always fails with an actionable error,
    /// so model-driven techniques degrade gracefully (tests skip, the
    /// simulator and model-free baselines keep working).
    pub struct PjrtRuntime {
        never: std::convert::Infallible,
    }

    impl PjrtRuntime {
        pub fn new(art_dir: impl AsRef<Path>) -> Result<Self> {
            let _ = art_dir.as_ref();
            bail!(
                "start-sim was built without the `pjrt` cargo feature; \
                 rebuild with `--features pjrt` (requires the vendored `xla` \
                 crate) to execute AOT models"
            )
        }

        pub fn artifact_dir(&self) -> &Path {
            match self.never {}
        }

        pub fn platform(&self) -> String {
            match self.never {}
        }

        pub fn load(&self, _file_name: &str) -> Result<Executable> {
            match self.never {}
        }
    }
}

#[cfg(not(feature = "pjrt"))]
pub use stub_backend::{Executable, PjrtRuntime};
