//! Artifact manifest: the shape/constant contract emitted by
//! `python/compile/aot.py`.  Everything the Rust side needs to marshal
//! feature matrices correctly is read from here at startup — no dimension
//! is duplicated in Rust code.

use crate::util::json::{self, Json};
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Constants of the shared generative model (mirrors `synth.GEN`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GenerativeConstants {
    pub alpha_min: f64,
    pub alpha_span: f64,
    pub alpha_gain: f64,
    pub alpha_mid: f64,
    pub contention_weight: f64,
    pub hetero_weight: f64,
    pub beta_base: f64,
    pub beta_demand_lo: f64,
    pub beta_demand_w: f64,
    pub beta_load_w: f64,
    pub contention_knee: f64,
}

impl GenerativeConstants {
    fn from_json(v: &Json) -> Result<Self> {
        Ok(Self {
            alpha_min: v.req_f64("alpha_min")?,
            alpha_span: v.req_f64("alpha_span")?,
            alpha_gain: v.req_f64("alpha_gain")?,
            alpha_mid: v.req_f64("alpha_mid")?,
            contention_weight: v.req_f64("contention_weight")?,
            hetero_weight: v.req_f64("hetero_weight")?,
            beta_base: v.req_f64("beta_base")?,
            beta_demand_lo: v.req_f64("beta_demand_lo")?,
            beta_demand_w: v.req_f64("beta_demand_w")?,
            beta_load_w: v.req_f64("beta_load_w")?,
            contention_knee: v.req_f64("contention_knee")?,
        })
    }
}

/// Parsed `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub n_hosts: usize,
    pub m_feats: usize,
    pub q_tasks: usize,
    pub p_feats: usize,
    pub hidden: usize,
    pub igru_hidden: usize,
    pub rollout_steps: usize,
    pub rollout_batch: usize,
    pub ema_weight: f64,
    pub k_default: f64,
    pub infer_period_s: f64,
    pub infer_window_s: f64,
    pub generative: GenerativeConstants,
    pub artifacts: BTreeMap<String, String>,
}

impl Manifest {
    /// Load from `<art_dir>/manifest.json`.
    pub fn load(art_dir: impl AsRef<Path>) -> Result<Manifest> {
        let path = art_dir.as_ref().join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    /// Parse manifest JSON text.
    pub fn parse(text: &str) -> Result<Manifest> {
        let v = json::parse(text).context("parsing manifest.json")?;
        let artifacts = v
            .get("artifacts")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing artifacts map"))?
            .iter()
            .map(|(k, val)| {
                val.as_str()
                    .map(|s| (k.clone(), s.to_string()))
                    .ok_or_else(|| anyhow!("artifact {k:?} is not a string"))
            })
            .collect::<Result<BTreeMap<_, _>>>()?;
        Ok(Manifest {
            n_hosts: v.req_usize("n_hosts")?,
            m_feats: v.req_usize("m_feats")?,
            q_tasks: v.req_usize("q_tasks")?,
            p_feats: v.req_usize("p_feats")?,
            hidden: v.req_usize("hidden")?,
            igru_hidden: v.req_usize("igru_hidden")?,
            rollout_steps: v.req_usize("rollout_steps")?,
            rollout_batch: v.req_usize("rollout_batch")?,
            ema_weight: v.req_f64("ema_weight")?,
            k_default: v.req_f64("k_default")?,
            infer_period_s: v.req_f64("infer_period_s")?,
            infer_window_s: v.req_f64("infer_window_s")?,
            generative: GenerativeConstants::from_json(
                v.get("generative").ok_or_else(|| anyhow!("manifest missing generative"))?,
            )?,
            artifacts,
        })
    }

    /// Elements in one M_H matrix.
    pub fn mh_len(&self) -> usize {
        self.n_hosts * self.m_feats
    }

    /// Elements in one M_T matrix.
    pub fn mt_len(&self) -> usize {
        self.q_tasks * self.p_feats
    }

    /// File name of a required artifact.
    pub fn artifact(&self, key: &str) -> Result<&str> {
        self.artifacts
            .get(key)
            .map(String::as_str)
            .ok_or_else(|| anyhow!("manifest has no artifact {key:?}"))
    }

    /// Canned manifest mirroring `python/compile/dims.py` defaults, for
    /// tests and benches that exercise the simulator without an artifact
    /// directory (its `artifacts` map is empty, so model loads will fail
    /// gracefully rather than dispatch).
    pub fn test_default() -> Manifest {
        Manifest {
            n_hosts: 20,
            m_feats: 12,
            q_tasks: 10,
            p_feats: 8,
            hidden: 32,
            igru_hidden: 32,
            rollout_steps: 5,
            rollout_batch: 8,
            ema_weight: 0.8,
            k_default: 1.5,
            infer_period_s: 1.0,
            infer_window_s: 5.0,
            generative: GenerativeConstants {
                alpha_min: 1.15,
                alpha_span: 2.85,
                alpha_gain: 4.0,
                alpha_mid: 0.65,
                contention_weight: 0.5,
                hetero_weight: 0.4,
                beta_base: 1.0,
                beta_demand_lo: 0.4,
                beta_demand_w: 1.2,
                beta_load_w: 0.8,
                contention_knee: 1.2,
            },
            artifacts: BTreeMap::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "n_hosts": 20, "m_feats": 12, "q_tasks": 10, "p_feats": 8,
        "hidden": 32, "igru_hidden": 32, "rollout_steps": 5,
        "rollout_batch": 8, "ema_weight": 0.8, "k_default": 1.5,
        "infer_period_s": 1.0, "infer_window_s": 5.0,
        "generative": {
            "alpha_min": 1.15, "alpha_span": 2.85, "alpha_gain": 4.0,
            "alpha_mid": 0.65, "contention_weight": 0.5,
            "hetero_weight": 0.4, "beta_base": 1.0, "beta_demand_lo": 0.4,
            "beta_demand_w": 1.2, "beta_load_w": 0.8, "contention_knee": 1.2
        },
        "artifacts": {"start_step": "start_step.hlo.txt"}
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.n_hosts, 20);
        assert_eq!(m.mh_len(), 240);
        assert_eq!(m.mt_len(), 80);
        assert_eq!(m.artifact("start_step").unwrap(), "start_step.hlo.txt");
        assert!(m.artifact("nope").is_err());
        assert_eq!(m.generative.alpha_min, 1.15);
    }

    #[test]
    fn missing_field_is_error() {
        let bad = SAMPLE.replace("\"n_hosts\": 20,", "");
        assert!(Manifest::parse(&bad).is_err());
    }
}
