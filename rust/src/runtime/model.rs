//! Typed wrappers over the AOT executables: the START Encoder-LSTM and the
//! IGRU-SD GRU, with shape checking against the manifest.

use super::{Executable, Manifest, PjrtRuntime};
use anyhow::{ensure, Result};

/// Recurrent state of the 2-layer LSTM (h1, c1, h2, c2), batch 1.
#[derive(Clone, Debug)]
pub struct LstmState {
    pub h1: Vec<f32>,
    pub c1: Vec<f32>,
    pub h2: Vec<f32>,
    pub c2: Vec<f32>,
}

impl LstmState {
    pub fn zeros(hidden: usize) -> Self {
        Self {
            h1: vec![0.0; hidden],
            c1: vec![0.0; hidden],
            h2: vec![0.0; hidden],
            c2: vec![0.0; hidden],
        }
    }
}

/// The START Encoder-LSTM, loaded from AOT artifacts.
///
/// Three variants are compiled: single step (stateful, one tick), fused
/// T-step rollout (one dispatch per prediction window — the hot path), and
/// a batch-8 rollout used to amortize dispatch across concurrent jobs.
pub struct StartModel {
    step: Executable,
    rollout: Executable,
    rollout_b8: Executable,
    pub manifest: Manifest,
}

impl StartModel {
    pub fn load(rt: &PjrtRuntime, manifest: &Manifest) -> Result<Self> {
        Ok(Self {
            step: rt.load(manifest.artifact("start_step")?)?,
            rollout: rt.load(manifest.artifact("start_rollout")?)?,
            rollout_b8: rt.load(manifest.artifact("start_rollout_b8")?)?,
            manifest: manifest.clone(),
        })
    }

    /// One inference tick: (α, β, next state).
    pub fn step(&self, m_h: &[f32], m_t: &[f32], state: &LstmState) -> Result<(f64, f64, LstmState)> {
        let m = &self.manifest;
        ensure!(m_h.len() == m.mh_len(), "m_h len {} != {}", m_h.len(), m.mh_len());
        ensure!(m_t.len() == m.mt_len(), "m_t len {} != {}", m_t.len(), m.mt_len());
        let h = m.hidden;
        let outs = self.step.run_f32(&[
            (m_h, &[1, m.n_hosts, m.m_feats]),
            (m_t, &[1, m.q_tasks, m.p_feats]),
            (&state.h1, &[1, h]),
            (&state.c1, &[1, h]),
            (&state.h2, &[1, h]),
            (&state.c2, &[1, h]),
        ])?;
        ensure!(outs.len() == 6, "expected 6 outputs, got {}", outs.len());
        let next = LstmState {
            h1: outs[2].clone(),
            c1: outs[3].clone(),
            h2: outs[4].clone(),
            c2: outs[5].clone(),
        };
        Ok((outs[0][0] as f64, outs[1][0] as f64, next))
    }

    /// Fused T-step rollout from η₀ = 0: single PJRT dispatch.
    ///
    /// `m_h_seq`/`m_t_seq` are T concatenated matrices (already
    /// EMA-smoothed by the feature extractor).
    pub fn rollout(&self, m_h_seq: &[f32], m_t_seq: &[f32]) -> Result<(f64, f64)> {
        let m = &self.manifest;
        let t = m.rollout_steps;
        ensure!(m_h_seq.len() == t * m.mh_len(), "m_h_seq len {}", m_h_seq.len());
        ensure!(m_t_seq.len() == t * m.mt_len(), "m_t_seq len {}", m_t_seq.len());
        let outs = self.rollout.run_f32(&[
            (m_h_seq, &[t, 1, m.n_hosts, m.m_feats]),
            (m_t_seq, &[t, 1, m.q_tasks, m.p_feats]),
        ])?;
        ensure!(outs.len() == 2, "expected 2 outputs, got {}", outs.len());
        Ok((outs[0][0] as f64, outs[1][0] as f64))
    }

    /// Batched rollout over `rollout_batch` jobs in one dispatch.
    ///
    /// Layout matches the AOT spec: (T, B, n, m) i.e. for each timestep the
    /// B jobs' matrices are contiguous.  Returns B (α, β) pairs.
    pub fn rollout_batch(&self, m_h_seq: &[f32], m_t_seq: &[f32]) -> Result<Vec<(f64, f64)>> {
        let m = &self.manifest;
        let (t, b) = (m.rollout_steps, m.rollout_batch);
        ensure!(m_h_seq.len() == t * b * m.mh_len(), "m_h_seq len {}", m_h_seq.len());
        ensure!(m_t_seq.len() == t * b * m.mt_len(), "m_t_seq len {}", m_t_seq.len());
        let outs = self.rollout_b8.run_f32(&[
            (m_h_seq, &[t, b, m.n_hosts, m.m_feats]),
            (m_t_seq, &[t, b, m.q_tasks, m.p_feats]),
        ])?;
        ensure!(outs.len() == 2 && outs[0].len() == b, "bad batched output");
        Ok((0..b).map(|i| (outs[0][i] as f64, outs[1][i] as f64)).collect())
    }
}

/// The IGRU-SD baseline network (GRU over the task matrix).
pub struct IgruModel {
    step: Executable,
    pub manifest: Manifest,
}

impl IgruModel {
    pub fn load(rt: &PjrtRuntime, manifest: &Manifest) -> Result<Self> {
        Ok(Self { step: rt.load(manifest.artifact("igru_step")?)?, manifest: manifest.clone() })
    }

    /// One tick: predicted next-interval per-task CPU demand + next hidden.
    pub fn step(&self, m_t: &[f32], hidden: &[f32]) -> Result<(Vec<f32>, Vec<f32>)> {
        let m = &self.manifest;
        ensure!(m_t.len() == m.mt_len(), "m_t len {}", m_t.len());
        ensure!(hidden.len() == m.igru_hidden, "hidden len {}", hidden.len());
        let outs = self
            .step
            .run_f32(&[(m_t, &[1, m.q_tasks, m.p_feats]), (hidden, &[1, m.igru_hidden])])?;
        ensure!(outs.len() == 2, "expected 2 outputs, got {}", outs.len());
        Ok((outs[0].clone(), outs[1].clone()))
    }

    pub fn zero_hidden(&self) -> Vec<f32> {
        vec![0.0; self.manifest.igru_hidden]
    }
}
