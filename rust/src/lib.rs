//! # start-sim
//!
//! Full-system reproduction of *START: Straggler Prediction and Mitigation
//! for Cloud Computing Environments using Encoder LSTM Networks* (Tuli et
//! al., 2021) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L1/L2 (build time)** — the Encoder-LSTM (and IGRU-SD baseline)
//!   authored in JAX over Pallas kernels, trained and AOT-lowered to HLO
//!   text by `make artifacts` (`python/compile/`).
//! * **L3 (runtime, this crate)** — a CloudSim-style event-driven cloud
//!   simulator whose world state is a layered module family
//!   (`sim::world::{ids, registry, topology, load, rates}`, DESIGN.md
//!   §3/§13) with `#[repr(transparent)]` entity-id newtypes and
//!   zero-alloc borrowed query views, Weibull fault injection,
//!   PlanetLab-like trace generation, the START coordinator (prediction
//!   via PJRT + speculation/re-run mitigation, Algorithm 1), eight
//!   baseline straggler managers, and the experiment harness
//!   regenerating every figure in the paper's evaluation (DESIGN.md §4).
//!
//! Python never runs on the request path: the binary is self-contained
//! once `artifacts/` is built.  See `DESIGN.md` at the repo root for the
//! full architecture.

pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod experiments;
pub mod mitigation;
pub mod ml;
pub mod pareto;
pub mod predictor;
pub mod runtime;
pub mod scheduler;
pub mod sim;
pub mod trace;
pub mod util;

/// Default artifact directory (relative to the repo root / CWD).
pub const DEFAULT_ARTIFACT_DIR: &str = "artifacts";

/// Locate the artifact directory: `$START_SIM_ARTIFACTS`, CWD, or walking
/// up from the current directory (so `cargo test`/`cargo bench` work
/// anywhere in the workspace).
pub fn find_artifact_dir() -> std::path::PathBuf {
    if let Ok(dir) = std::env::var("START_SIM_ARTIFACTS") {
        return dir.into();
    }
    let mut cur = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = cur.join(DEFAULT_ARTIFACT_DIR);
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !cur.pop() {
            return DEFAULT_ARTIFACT_DIR.into();
        }
    }
}

/// CLI entrypoint (see `main.rs`); lives here so examples can reuse it.
pub fn launcher_main() -> anyhow::Result<()> {
    let args = util::cli::Args::from_env()?;
    match args.subcommand.as_deref() {
        Some("info") | None => {
            let dir = find_artifact_dir();
            println!("start-sim — START reproduction (see DESIGN.md)");
            println!("artifact dir: {}", dir.display());
            let manifest = runtime::Manifest::load(&dir)?;
            println!(
                "model: encoder({}x{}+{}x{}) -> lstm {}x2 -> (alpha,beta); T={} I-batch={}",
                manifest.n_hosts, manifest.m_feats, manifest.q_tasks, manifest.p_feats,
                manifest.hidden, manifest.rollout_steps, manifest.rollout_batch
            );
            println!("subcommands: info | simulate | experiment");
            println!(
                "simulate --trace <path>: stream a JSONL event trace (the only \
                 replayable format — replay parity is checked after the run); \
                 a .csv path writes a flat export only, no replay (DESIGN.md section 10)"
            );
            println!(
                "experiment <id> [--resume] [--keep-going] [--retries N] \
                 [--cell-timeout SECS] [--compact]: fault-tolerant batch runner — \
                 completed cells are journaled to <out>/journal/<id>.results.jsonl, \
                 an interrupted run resumes bit-identically, and --compact rewrites \
                 the journal keeping the last record per cell (DESIGN.md section 12)"
            );
            Ok(())
        }
        Some("simulate") => {
            let mut cfg = config::SimConfig::paper_defaults();
            cfg.apply_cli(&args)?;
            let trace_path = args.opt_path("trace");
            let sink = match &trace_path {
                Some(p) => sim::TraceSink::file(p)?,
                None => sim::TraceSink::off(),
            };
            // Full model stack when artifacts are present; model-free
            // techniques degrade to a hermetic run otherwise (canned
            // manifest — the simulator itself needs no AOT models).
            let (m, mut sink) = match coordinator::Models::load_default() {
                Ok(models) => coordinator::run_one_traced(&cfg, &models, sink)?,
                Err(e) => {
                    eprintln!("note: artifacts unavailable ({e}); running hermetic model-free");
                    coordinator::run_one_hermetic(&cfg, sink)?
                }
            };
            let n_events = sink.finish()?;
            println!("technique={} jobs={} tasks={}", cfg.technique.name(), m.jobs_done, m.tasks_done);
            println!("avg exec time      : {:.1} s", m.avg_execution_time());
            println!("energy             : {:.2} kWh", m.total_energy_kwh());
            println!("contention         : {:.3}", m.avg_contention());
            println!("SLA violation rate : {:.3}", m.sla_violation_rate());
            println!("straggler MAPE     : {:.1} %", m.straggler_mape());
            println!("F1                 : {:.3}", m.confusion.f1());
            println!("overhead           : {:.2} s ({} spec, {} rerun)",
                m.manager_overhead_s(), m.speculations, m.reruns);
            if args.flag("profile") {
                println!("phase profile:");
                for p in sim::Phase::ALL {
                    println!(
                        "  {:<10} {:>10.4} s  ({} calls)",
                        p.name(),
                        m.profile.seconds(p),
                        m.profile.calls(p)
                    );
                    if p == sim::Phase::Predict {
                        // Manager-reported sub-spans (breakdown of the
                        // predict row; omitted when uninstrumented).
                        for (i, name) in sim::trace::PredictSpans::NAMES.iter().enumerate() {
                            let (s, c) = m.profile.predict_span(i);
                            if c > 0 {
                                println!("    predict/{:<8} {:>6.4} s  ({} intervals)", name, s, c);
                            }
                        }
                    }
                }
                println!("  {:<10} {:>10.4} s", "total", m.profile.total_seconds());
            }
            if let Some(path) = &trace_path {
                println!("trace              : {} events -> {}", n_events, path.display());
                // Keystone invariant, checked on every traced CLI run:
                // the JSONL stream alone re-derives the metrics exactly.
                // CSV is a flat export only — JSONL is the sole replayable
                // trace format (DESIGN.md §10).
                if path.extension().and_then(|e| e.to_str()) != Some("csv") {
                    let events = sim::trace::load_jsonl(path)?;
                    let replayed = sim::trace::replay(&events);
                    match m.diff_deterministic(&replayed) {
                        None => println!("replay parity      : OK"),
                        Some(d) => anyhow::bail!("replay parity FAILED: {d}"),
                    }
                } else {
                    println!("replay parity      : skipped (.csv is export-only; use .jsonl for replay)");
                }
            }
            if let Some(out) = args.opt_path("out") {
                if let Some(dir) = out.parent() {
                    if !dir.as_os_str().is_empty() {
                        std::fs::create_dir_all(dir)?;
                    }
                }
                std::fs::write(&out, experiments::common::metrics_json(&m).dump())?;
                println!("metrics            : {}", out.display());
            }
            Ok(())
        }
        Some("experiment") => experiments::run_from_cli(&args),
        Some(other) => anyhow::bail!("unknown subcommand {other:?} (try: info, simulate, experiment)"),
    }
}
