//! IGRU-SD predictor [22]: a GRU forecasts per-task resource requests;
//! a detection pass flags tasks whose predicted demand exceeds a threshold
//! as likely stragglers.  Critically (and per the paper's critique), it
//! sees only the **task** matrix — no host heterogeneity — which is why
//! its accuracy collapses when host composition churns (Fig. 9).

use crate::predictor::FeatureExtractor;
use crate::runtime::IgruModel;
use crate::sim::types::JobId;
use crate::sim::world::World;
use crate::trace::generative::T_CPU_REQ;
use anyhow::Result;
use std::collections::HashMap;
use std::rc::Rc;
use std::time::{Duration, Instant};

/// GRU-based resource-request prediction + threshold detection.
pub struct IgruPredictor {
    model: Rc<IgruModel>,
    /// Per-job recurrent hidden state.
    hidden: HashMap<JobId, Vec<f32>>,
    /// Detection threshold on predicted normalized CPU demand.
    pub threshold: f64,
    mt_scratch: Vec<f32>,
    /// Wall-time accumulators for the Predict-phase sub-span breakdown
    /// (feature assembly vs GRU dispatch), drained once per interval by
    /// the manager via [`IgruPredictor::take_spans`] — same shape as
    /// `StartPredictor` so Fig.-style phase profiles compare like for
    /// like across techniques.
    span_features: Duration,
    span_dispatch: Duration,
}

impl IgruPredictor {
    pub fn new(model: Rc<IgruModel>, threshold: f64) -> Self {
        let mt = model.manifest.mt_len();
        Self {
            model,
            hidden: HashMap::new(),
            threshold,
            mt_scratch: vec![0.0; mt],
            span_features: Duration::ZERO,
            span_dispatch: Duration::ZERO,
        }
    }

    /// Drain the accumulated (feature-assembly, dispatch) spans.
    pub fn take_spans(&mut self) -> (Duration, Duration) {
        (
            std::mem::take(&mut self.span_features),
            std::mem::take(&mut self.span_dispatch),
        )
    }

    /// Advance the job's GRU one tick; returns per-task-slot predicted
    /// next-interval CPU demand.
    pub fn step(&mut self, w: &World, fx: &FeatureExtractor, job: JobId) -> Result<Vec<f32>> {
        let t0 = Instant::now();
        fx.build_m_t(w, job, &mut self.mt_scratch);
        let h = self
            .hidden
            .entry(job)
            .or_insert_with(|| self.model.zero_hidden())
            .clone();
        let t1 = Instant::now();
        self.span_features += t1 - t0;
        let stepped = self.model.step(&self.mt_scratch, &h);
        self.span_dispatch += t1.elapsed();
        let (pred, h2) = stepped?;
        self.hidden.insert(job, h2);
        Ok(pred)
    }

    /// Detection pass: expected straggler count = tasks whose predicted
    /// demand exceeds `threshold` × their current request.
    pub fn expected_stragglers(&mut self, w: &World, fx: &FeatureExtractor, job: JobId) -> Result<(f64, Vec<usize>)> {
        let pred = self.step(w, fx, job)?;
        let m = &self.model.manifest;
        let mut flagged = Vec::new();
        for (slot, &tid) in w.job(job).tasks.iter().take(m.q_tasks).enumerate() {
            if !w.task(tid).is_active() {
                continue;
            }
            let cur = self.mt_scratch[slot * m.p_feats + T_CPU_REQ] as f64;
            if pred[slot] as f64 > self.threshold * cur.max(0.05) {
                flagged.push(slot);
            }
        }
        Ok((flagged.len() as f64, flagged))
    }

    /// Drop state for a finished job.
    pub fn forget(&mut self, job: JobId) {
        self.hidden.remove(&job);
    }
}
