//! IGRU-SD predictor [22]: a GRU forecasts per-task resource requests;
//! a detection pass flags tasks whose predicted demand exceeds a threshold
//! as likely stragglers.  Critically (and per the paper's critique), it
//! sees only the **task** matrix — no host heterogeneity — which is why
//! its accuracy collapses when host composition churns (Fig. 9).

use crate::predictor::FeatureExtractor;
use crate::runtime::IgruModel;
use crate::sim::types::JobId;
use crate::sim::world::World;
use crate::trace::generative::T_CPU_REQ;
use anyhow::Result;
use std::collections::HashMap;
use std::rc::Rc;

/// GRU-based resource-request prediction + threshold detection.
pub struct IgruPredictor {
    model: Rc<IgruModel>,
    /// Per-job recurrent hidden state.
    hidden: HashMap<JobId, Vec<f32>>,
    /// Detection threshold on predicted normalized CPU demand.
    pub threshold: f64,
    mt_scratch: Vec<f32>,
}

impl IgruPredictor {
    pub fn new(model: Rc<IgruModel>, threshold: f64) -> Self {
        let mt = model.manifest.mt_len();
        Self { model, hidden: HashMap::new(), threshold, mt_scratch: vec![0.0; mt] }
    }

    /// Advance the job's GRU one tick; returns per-task-slot predicted
    /// next-interval CPU demand.
    pub fn step(&mut self, w: &World, fx: &FeatureExtractor, job: JobId) -> Result<Vec<f32>> {
        fx.build_m_t(w, job, &mut self.mt_scratch);
        let h = self
            .hidden
            .entry(job)
            .or_insert_with(|| self.model.zero_hidden())
            .clone();
        let (pred, h2) = self.model.step(&self.mt_scratch, &h)?;
        self.hidden.insert(job, h2);
        Ok(pred)
    }

    /// Detection pass: expected straggler count = tasks whose predicted
    /// demand exceeds `threshold` × their current request.
    pub fn expected_stragglers(&mut self, w: &World, fx: &FeatureExtractor, job: JobId) -> Result<(f64, Vec<usize>)> {
        let pred = self.step(w, fx, job)?;
        let m = &self.model.manifest;
        let mut flagged = Vec::new();
        for (slot, &tid) in w.job(job).tasks.iter().take(m.q_tasks).enumerate() {
            if !w.task(tid).is_active() {
                continue;
            }
            let cur = self.mt_scratch[slot * m.p_feats + T_CPU_REQ] as f64;
            if pred[slot] as f64 > self.threshold * cur.max(0.05) {
                flagged.push(slot);
            }
        }
        Ok((flagged.len() as f64, flagged))
    }

    /// Drop state for a finished job.
    pub fn forget(&mut self, job: JobId) {
        self.hidden.remove(&job);
    }
}
