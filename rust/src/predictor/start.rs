//! The START predictor: Encoder-LSTM (via PJRT) → Pareto (α, β) → E_S.
//!
//! This is the paper's §3.2 inference loop.  The hot path uses the fused
//! T-step rollout artifact (one PJRT dispatch per prediction instead of
//! T), and packs up to `rollout_batch` jobs per dispatch via the batched
//! artifact — see DESIGN.md §8.

use crate::pareto::Pareto;
use crate::predictor::FeatureExtractor;
use crate::runtime::StartModel;
use crate::sim::types::JobId;
use crate::sim::world::World;
use anyhow::Result;
use std::rc::Rc;
use std::time::{Duration, Instant};

/// A (job → E_S) prediction.
#[derive(Clone, Copy, Debug)]
pub struct StragglerPrediction {
    pub job: JobId,
    pub alpha: f64,
    pub beta: f64,
    /// Expected straggler count E_S (Eq. 4).
    pub expected: f64,
}

/// Wraps the AOT Encoder-LSTM with feature plumbing and Pareto math.
pub struct StartPredictor {
    model: Rc<StartModel>,
    /// Straggler parameter k (adapted online by the engine).
    pub k: f64,
    /// Effective history window (≤ rollout_steps; smaller = the Fig. 2 "T"
    /// ablation — older steps are overwritten with the oldest kept step).
    pub window_steps: usize,
    /// Scratch buffers (avoid per-prediction allocation on the hot path).
    mh_window: Vec<f32>,
    mt_scratch: Vec<f32>,
    mh_batch: Vec<f32>,
    mt_batch: Vec<f32>,
    /// Wall-time accumulators for the Predict-phase sub-span breakdown
    /// (feature assembly vs PJRT dispatch) across predictions; drained
    /// once per interval by the manager via [`StartPredictor::take_spans`].
    span_features: Duration,
    span_dispatch: Duration,
}

impl StartPredictor {
    pub fn new(model: Rc<StartModel>, k: f64) -> Self {
        let m = &model.manifest;
        let (t, b) = (m.rollout_steps, m.rollout_batch);
        let (mh, mt) = (m.mh_len(), m.mt_len());
        Self {
            k,
            window_steps: t,
            mh_window: Vec::with_capacity(t * mh),
            mt_scratch: vec![0.0; mt],
            mh_batch: vec![0.0; t * b * mh],
            mt_batch: vec![0.0; t * b * mt],
            span_features: Duration::ZERO,
            span_dispatch: Duration::ZERO,
            model,
        }
    }

    /// Drain the accumulated (feature-assembly, PJRT-dispatch) spans.
    pub fn take_spans(&mut self) -> (Duration, Duration) {
        (
            std::mem::take(&mut self.span_features),
            std::mem::take(&mut self.span_dispatch),
        )
    }

    /// Predict (α, β, E_S) for one job: fused rollout, single dispatch.
    pub fn predict(
        &mut self,
        w: &World,
        fx: &FeatureExtractor,
        job: JobId,
    ) -> Result<StragglerPrediction> {
        let (t, mh_len, mt_len) =
            (self.model.manifest.rollout_steps, self.model.manifest.mh_len(), self.model.manifest.mt_len());
        let t0 = Instant::now();
        fx.m_h_window(&mut self.mh_window);
        self.truncate_window(t, mh_len);
        fx.build_m_t(w, job, &mut self.mt_scratch);
        // M_T window: repeat the current task matrix across T steps (task
        // requirements are static within a prediction window).
        let mut mt_seq = vec![0.0f32; t * mt_len];
        for step in 0..t {
            mt_seq[step * mt_len..(step + 1) * mt_len].copy_from_slice(&self.mt_scratch);
        }
        let t1 = Instant::now();
        self.span_features += t1 - t0;
        let rolled = self.model.rollout(&self.mh_window, &mt_seq);
        self.span_dispatch += t1.elapsed();
        let (alpha, beta) = rolled?;
        Ok(self.to_prediction(w, job, alpha, beta))
    }

    /// Predict for up to `rollout_batch` jobs in one PJRT dispatch,
    /// padding unused batch lanes with zeros.
    pub fn predict_batch(
        &mut self,
        w: &World,
        fx: &FeatureExtractor,
        jobs: &[JobId],
    ) -> Result<Vec<StragglerPrediction>> {
        let m = &self.model.manifest;
        let (t, b) = (m.rollout_steps, m.rollout_batch);
        let (mh_len, mt_len) = (m.mh_len(), m.mt_len());
        assert!(jobs.len() <= b, "at most {b} jobs per batched dispatch");
        let t0 = Instant::now();
        fx.m_h_window(&mut self.mh_window);
        self.truncate_window(t, mh_len);
        self.mh_batch.fill(0.0);
        self.mt_batch.fill(0.0);
        // Layout (T, B, …): per timestep, B contiguous matrices.
        for step in 0..t {
            let mh_src = &self.mh_window[step * mh_len..(step + 1) * mh_len];
            for lane in 0..b {
                let dst = (step * b + lane) * mh_len;
                self.mh_batch[dst..dst + mh_len].copy_from_slice(mh_src);
            }
        }
        for (lane, &job) in jobs.iter().enumerate() {
            fx.build_m_t(w, job, &mut self.mt_scratch);
            for step in 0..t {
                let dst = (step * b + lane) * mt_len;
                self.mt_batch[dst..dst + mt_len].copy_from_slice(&self.mt_scratch);
            }
        }
        let t1 = Instant::now();
        self.span_features += t1 - t0;
        let rolled = self.model.rollout_batch(&self.mh_batch, &self.mt_batch);
        self.span_dispatch += t1.elapsed();
        let pairs = rolled?;
        Ok(jobs
            .iter()
            .zip(pairs)
            .map(|(&job, (alpha, beta))| self.to_prediction(w, job, alpha, beta))
            .collect())
    }

    /// Emulate a shorter history window T′ < T by overwriting the oldest
    /// (T − T′) steps with the oldest retained step.
    fn truncate_window(&mut self, t: usize, mh_len: usize) {
        let keep = self.window_steps.clamp(1, t);
        if keep == t {
            return;
        }
        let src_start = (t - keep) * mh_len;
        let src: Vec<f32> = self.mh_window[src_start..src_start + mh_len].to_vec();
        for step in 0..(t - keep) {
            self.mh_window[step * mh_len..(step + 1) * mh_len].copy_from_slice(&src);
        }
    }

    fn to_prediction(
        &self,
        w: &World,
        job: JobId,
        alpha: f64,
        beta: f64,
    ) -> StragglerPrediction {
        let q = w.job(job).tasks.len();
        let expected = Pareto::new(alpha.max(1.001), beta.max(1e-6))
            .map(|p| p.expected_stragglers(q, self.k))
            .unwrap_or(0.0);
        StragglerPrediction { job, alpha, beta, expected }
    }
}
