//! Straggler predictors: the START Encoder-LSTM (via PJRT), the IGRU-SD
//! GRU baseline (via PJRT), and the RPPS ARIMA baseline — plus the feature
//! extractor that turns simulator state into the model's (M_H, M_T)
//! matrices (paper Fig. 3).

pub mod features;
pub mod igru;
pub mod rpps;
pub mod start;

pub use features::FeatureExtractor;
pub use igru::IgruPredictor;
pub use rpps::RppsPredictor;
pub use start::StartPredictor;
