//! Feature extraction (paper Fig. 3): host matrix M_H (n × m) and task
//! matrix M_T (q′ × p), EMA-smoothed with weight 0.8 on the latest matrix
//! (§3.2), plus the sliding T-step window the rollout artifact consumes.
//!
//! Column layouts must match `python/compile/dims.py` — the indices are
//! imported from `trace::generative` which is golden-pinned to Python.

use crate::runtime::Manifest;
use crate::sim::types::*;
use crate::sim::world::World;
use crate::trace::generative::*;
use std::collections::VecDeque;

/// Builds and smooths feature matrices from the live world.
pub struct FeatureExtractor {
    pub n_hosts: usize,
    pub m_feats: usize,
    pub q_tasks: usize,
    pub p_feats: usize,
    rollout_steps: usize,
    ema_weight: f64,
    /// EMA-smoothed M_H and its last `rollout_steps` snapshots.
    ema_m_h: Vec<f32>,
    history: VecDeque<Vec<f32>>,
    /// Scratch for raw snapshot (avoids per-tick allocation).
    scratch: Vec<f32>,
    /// Scratch for per-slot aggregation counts in `build_m_h`.
    slot_scratch: Vec<f32>,
    initialized: bool,
}

impl FeatureExtractor {
    pub fn new(manifest: &Manifest) -> Self {
        Self {
            n_hosts: manifest.n_hosts,
            m_feats: manifest.m_feats,
            q_tasks: manifest.q_tasks,
            p_feats: manifest.p_feats,
            rollout_steps: manifest.rollout_steps,
            ema_weight: manifest.ema_weight,
            ema_m_h: vec![0.0; manifest.mh_len()],
            history: VecDeque::with_capacity(manifest.rollout_steps + 1),
            scratch: vec![0.0; manifest.mh_len()],
            slot_scratch: vec![0.0; manifest.n_hosts],
            initialized: false,
        }
    }

    /// Build the raw (unsmoothed) M_H from the world.  Physical hosts are
    /// aggregated onto `n_hosts` slots (`host.id % n_hosts`): utilizations
    /// and capacities are averaged, task counts summed — the paper's n-host
    /// abstraction over a larger VM fleet.
    pub fn build_m_h(&mut self, w: &World, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.n_hosts * self.m_feats);
        out.fill(0.0);
        let (max_mips, max_ram, max_disk, max_bw) = w.fleet_max();
        let mut slot_count = std::mem::take(&mut self.slot_scratch);
        slot_count.fill(0.0);
        for h in &w.hosts {
            let slot = h.id.raw() % self.n_hosts;
            let row = &mut out[slot * self.m_feats..(slot + 1) * self.m_feats];
            let up = h.is_up(w.now);
            slot_count[slot] += 1.0;
            if up {
                row[H_CPU_UTIL] += w.host_cpu_util(h.id) as f32;
                row[H_RAM_UTIL] += w.host_ram_util(h.id) as f32;
                row[H_DISK_UTIL] += w.host_disk_util(h.id) as f32;
                row[H_BW_UTIL] += w.host_bw_util(h.id) as f32;
                row[H_IS_UP] += 1.0;
            }
            row[H_CPU_CAP] += (h.mips_total / max_mips) as f32;
            row[H_RAM_CAP] += (h.ram_gb / max_ram) as f32;
            row[H_DISK_CAP] += (h.disk_gb / max_disk) as f32;
            row[H_BW_CAP] += (h.bw_kbps / max_bw) as f32;
            row[H_POWER] += ((h.power_peak_w - h.power_idle_w) / 200.0) as f32;
            row[H_COST] += (h.cost_per_interval / 5.0) as f32;
            row[H_NTASKS] +=
                (w.host_task_count(h.id) as f64 / self.q_tasks as f64).min(1.0) as f32;
        }
        for slot in 0..self.n_hosts {
            let n = slot_count[slot].max(1.0);
            let row = &mut out[slot * self.m_feats..(slot + 1) * self.m_feats];
            for v in row.iter_mut() {
                *v /= n;
            }
            // is_up becomes the fraction of aggregated hosts serviceable;
            // round to the majority for the binary feature the net saw.
            row[H_IS_UP] = if row[H_IS_UP] >= 0.5 { 1.0 } else { 0.0 };
        }
        self.slot_scratch = slot_count;
    }

    /// Build M_T for a job: one row per task slot, zero-padded past q
    /// (paper §3.2: "if less than q′ tasks then rest q′ − q rows are 0").
    pub fn build_m_t(&self, w: &World, job: JobId, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.q_tasks * self.p_feats);
        out.fill(0.0);
        let (max_mips, max_ram, max_disk, max_bw) = w.fleet_max();
        let j = w.job(job);
        for (slot, &tid) in j.tasks.iter().take(self.q_tasks).enumerate() {
            let t = w.task(tid);
            if !t.is_active() && !matches!(t.state, TaskState::Completed { .. }) {
                continue;
            }
            let row = &mut out[slot * self.p_feats..(slot + 1) * self.p_feats];
            // Normalization ranges chosen so live values land in ~[0, 1],
            // matching the training distribution (synth.py reqs in [0,1]).
            row[T_CPU_REQ] = (t.demand.mips / 400.0).min(1.0) as f32;
            row[T_RAM_REQ] = (t.demand.ram_gb / 0.5).min(1.0) as f32;
            row[T_DISK_REQ] = (t.demand.disk_gb / (max_disk / 100.0).max(2.0)).min(1.0) as f32;
            row[T_BW_REQ] = (t.demand.bw_kbps / 0.4_f64.max(max_bw / 5.0)).min(1.0) as f32;
            row[T_PREV_HOST] = t
                .vm
                .map(|v| (w.vms[v].host.raw() % self.n_hosts) as f32 / self.n_hosts as f32)
                .unwrap_or(0.0);
            row[T_DEADLINE] = if j.deadline_driven { 1.0 } else { 0.0 };
            row[T_PROGRESS] = t.progress() as f32;
            row[T_ACTIVE] = if t.is_active() { 1.0 } else { 0.0 };
            let _ = max_mips;
            let _ = max_ram;
        }
    }

    /// Take the per-interval M_H snapshot: EMA-smooth and append to the
    /// rollout window.  Also publishes the smoothed matrix to
    /// `world.latest_m_h` for generative sampling at job submission.
    pub fn snapshot(&mut self, w: &mut World) {
        let mut scratch = std::mem::take(&mut self.scratch);
        self.build_m_h(w, &mut scratch);
        if !self.initialized {
            self.ema_m_h.copy_from_slice(&scratch);
            self.initialized = true;
        } else {
            let w8 = self.ema_weight as f32;
            for (e, &x) in self.ema_m_h.iter_mut().zip(scratch.iter()) {
                *e = w8 * x + (1.0 - w8) * *e;
            }
        }
        self.scratch = scratch;
        // Recycle the evicted window buffer instead of allocating a fresh
        // clone, and refresh `world.latest_m_h` in place — the snapshot
        // path allocates nothing once the window is warm.
        let mut slot = if self.history.len() == self.rollout_steps {
            self.history.pop_front().unwrap_or_default()
        } else {
            Vec::with_capacity(self.ema_m_h.len())
        };
        slot.resize(self.ema_m_h.len(), 0.0);
        slot.copy_from_slice(&self.ema_m_h);
        self.history.push_back(slot);
        w.latest_m_h.resize(self.ema_m_h.len(), 0.0);
        w.latest_m_h.copy_from_slice(&self.ema_m_h);
    }

    /// Current smoothed M_H.
    pub fn m_h(&self) -> &[f32] {
        &self.ema_m_h
    }

    /// The T-step M_H window for the rollout artifact, oldest first,
    /// left-padded by repeating the oldest snapshot until T are available.
    pub fn m_h_window(&self, out: &mut Vec<f32>) {
        out.clear();
        let t = self.rollout_steps;
        let len = self.history.len();
        let mh = self.n_hosts * self.m_feats;
        out.reserve(t * mh);
        for i in 0..t {
            let idx = if len == 0 {
                None
            } else if i + len >= t {
                Some(i + len - t)
            } else {
                Some(0)
            };
            match idx {
                Some(j) => out.extend_from_slice(&self.history[j]),
                None => out.extend(std::iter::repeat(0.0f32).take(mh)),
            }
        }
    }

    pub fn history_len(&self) -> usize {
        self.history.len()
    }
}

#[cfg(test)]
pub mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::runtime::Manifest;

    pub fn test_manifest() -> Manifest {
        Manifest::test_default()
    }

    fn add_job(w: &mut World, q: usize) -> JobId {
        let jid = JobId::new(w.n_jobs());
        let mut tasks = Vec::new();
        for _ in 0..q {
            let tid = TaskId::new(w.n_tasks());
            w.add_task(Task {
                id: tid,
                job: jid,
                length_mi: 1000.0,
                demand: TaskDemand { mips: 200.0, ram_gb: 0.25, disk_gb: 0.5, bw_kbps: 0.2 },
                state: TaskState::Pending,
                vm: None,
                last_vm: None,
                remaining_mi: 1000.0,
                submit_t: 0.0,
                first_start_t: None,
                restart_time: 0.0,
                restarts: 0,
                slowdown: 1.0,
                speculative_of: None,
                mitigated: false,
            });
            tasks.push(tid);
        }
        w.add_job(Job {
            id: jid,
            tasks,
            submit_t: 0.0,
            deadline_driven: true,
            sla_deadline: 1e9,
            sla_weight: 1.0,
            state: JobState::Active,
            true_alpha: 2.0,
            true_beta: 1.0,
        });
        jid
    }

    #[test]
    fn m_h_shape_and_ranges() {
        let w = World::new(&SimConfig::test_defaults());
        let mut fx = FeatureExtractor::new(&test_manifest());
        let mut out = vec![0.0f32; fx.n_hosts * fx.m_feats];
        fx.build_m_h(&w, &mut out);
        assert!(out.iter().all(|&x| (0.0..=1.5).contains(&x)), "out of range");
        // idle fleet: utilization columns zero, is_up one.
        for slot in 0..fx.n_hosts {
            let row = &out[slot * 12..(slot + 1) * 12];
            assert_eq!(row[H_CPU_UTIL], 0.0);
        }
    }

    #[test]
    fn m_t_zero_padding() {
        let mut w = World::new(&SimConfig::test_defaults());
        let job = add_job(&mut w, 3);
        let fx = FeatureExtractor::new(&test_manifest());
        let mut out = vec![0.0f32; fx.q_tasks * fx.p_feats];
        fx.build_m_t(&w, job, &mut out);
        for slot in 0..3 {
            assert_eq!(out[slot * 8 + T_ACTIVE], 1.0);
            assert!(out[slot * 8 + T_CPU_REQ] > 0.0);
            assert_eq!(out[slot * 8 + T_DEADLINE], 1.0);
        }
        for slot in 3..10 {
            let row = &out[slot * 8..(slot + 1) * 8];
            assert!(row.iter().all(|&x| x == 0.0), "padding row {slot} not zero");
        }
    }

    #[test]
    fn ema_smoothing_and_window() {
        let mut w = World::new(&SimConfig::test_defaults());
        let mut fx = FeatureExtractor::new(&test_manifest());
        fx.snapshot(&mut w);
        assert_eq!(fx.history_len(), 1);
        // Load one host then snapshot again: EMA moves by 0.8 of the delta.
        let before = fx.m_h()[H_CPU_UTIL];
        w.set_background_load(HostId::new(0), 0.5);
        fx.snapshot(&mut w);
        let after = fx.m_h()[H_CPU_UTIL];
        assert!(after > before);
        let mut window = Vec::new();
        fx.m_h_window(&mut window);
        assert_eq!(window.len(), 5 * 20 * 12);
        // First 4 window slots are the repeated oldest snapshot.
        assert_eq!(&window[0..240], &window[240..480]);
    }

    #[test]
    fn window_fills_after_t_snapshots() {
        let mut w = World::new(&SimConfig::test_defaults());
        let mut fx = FeatureExtractor::new(&test_manifest());
        for _ in 0..7 {
            fx.snapshot(&mut w);
        }
        assert_eq!(fx.history_len(), 5);
        assert!(!w.latest_m_h.is_empty());
    }
}
