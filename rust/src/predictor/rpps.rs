//! RPPS predictor [23]: ARIMA forecasting of workload resource demand,
//! thresholded into straggler detection.  Like IGRU-SD it ignores host
//! heterogeneity entirely — it only sees aggregate demand series — which
//! the paper uses to explain its Fig. 9 accuracy gap.

use crate::ml::Arima;
use crate::sim::types::JobId;
use crate::sim::world::World;
use std::collections::HashMap;

/// ARIMA(p, d, q) over the fleet-mean CPU-utilization series, plus a
/// per-job demand ratio to convert the forecast into a straggler count.
pub struct RppsPredictor {
    /// Fleet-mean CPU utilization history (one point per interval).
    history: Vec<f64>,
    pub p: usize,
    pub d: usize,
    pub q: usize,
    /// Straggler fraction scale: E_S ≈ q_tasks · clamp(forecast − knee).
    pub knee: f64,
    pub gain: f64,
    cache: HashMap<JobId, f64>,
}

impl RppsPredictor {
    pub fn new() -> Self {
        Self { history: Vec::new(), p: 2, d: 1, q: 1, knee: 0.45, gain: 2.0, cache: HashMap::new() }
    }

    /// Record this interval's fleet-mean CPU utilization.
    pub fn observe(&mut self, w: &World) {
        let mut total = 0.0;
        let mut up = 0usize;
        for h in &w.hosts {
            if h.is_up(w.now) {
                total += w.host_cpu_util(h.id);
                up += 1;
            }
        }
        self.history.push(if up > 0 { total / up as f64 } else { 0.0 });
        if self.history.len() > 512 {
            self.history.drain(..256);
        }
    }

    /// One-step-ahead utilization forecast (falls back to last value).
    pub fn forecast_util(&self) -> f64 {
        match Arima::fit(&self.history, self.p, self.d, self.q) {
            Some(m) => m.forecast(&self.history).clamp(0.0, 1.0),
            None => *self.history.last().unwrap_or(&0.0),
        }
    }

    /// Expected straggler count for a job: predicted overload pressure
    /// times the job size (no host awareness — by design of the baseline).
    pub fn expected_stragglers(&mut self, w: &World, job: JobId) -> f64 {
        let f = self.forecast_util();
        let q = w.job(job).tasks.len() as f64;
        let es = (q * self.gain * (f - self.knee).max(0.0)).min(q);
        self.cache.insert(job, es);
        es
    }

    /// Last prediction made for a job.
    pub fn last_prediction(&self, job: JobId) -> Option<f64> {
        self.cache.get(&job).copied()
    }
}

impl Default for RppsPredictor {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::sim::world::World;

    #[test]
    fn forecast_tracks_constant_load() {
        let w = World::new(&SimConfig::test_defaults());
        let mut r = RppsPredictor::new();
        for _ in 0..30 {
            r.observe(&w);
        }
        // Idle fleet: utilization 0, forecast 0.
        assert!(r.forecast_util() < 0.05);
    }

    #[test]
    fn forecast_rises_with_load_trend() {
        let mut r = RppsPredictor::new();
        // Inject a rising synthetic history directly.
        r.history = (0..40).map(|i| 0.3 + 0.01 * i as f64).collect();
        let f = r.forecast_util();
        assert!(f > 0.65, "forecast {f} should extrapolate the trend");
    }

    #[test]
    fn es_zero_below_knee() {
        let mut w = World::new(&SimConfig::test_defaults());
        let mut r = RppsPredictor::new();
        r.history = vec![0.1; 30];
        // a fake job
        w.add_job(crate::sim::types::Job {
            id: JobId::new(0),
            tasks: vec![],
            submit_t: 0.0,
            deadline_driven: false,
            sla_deadline: 0.0,
            sla_weight: 1.0,
            state: crate::sim::types::JobState::Active,
            true_alpha: 2.0,
            true_beta: 1.0,
        });
        assert_eq!(r.expected_stragglers(&w, JobId::new(0)), 0.0);
        assert_eq!(r.last_prediction(JobId::new(0)), Some(0.0));
    }
}
