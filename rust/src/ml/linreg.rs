//! Online ridge-regularized linear regression with confidence bounds — the
//! substrate of the Wrangler baseline [17], which fits a linear model on
//! node utilization counters and delays tasks whose straggler confidence
//! exceeds a threshold.
//!
//! Implementation: recursive least squares (Sherman–Morrison update of the
//! inverse Gram matrix), which also yields the predictive variance
//! xᵀ A⁻¹ x used as the confidence bound — the same quantity a Bayesian
//! linear model would report.

/// Online linear model y ≈ wᵀx with ridge prior.
#[derive(Clone, Debug)]
pub struct OnlineLinReg {
    dim: usize,
    /// Inverse Gram matrix A⁻¹ (row-major), initialized to I/λ.
    a_inv: Vec<f64>,
    /// Accumulated Xᵀy.
    b: Vec<f64>,
    /// Cached weights (recomputed on update).
    w: Vec<f64>,
    n: u64,
}

impl OnlineLinReg {
    pub fn new(dim: usize, ridge: f64) -> Self {
        let mut a_inv = vec![0.0; dim * dim];
        for i in 0..dim {
            a_inv[i * dim + i] = 1.0 / ridge.max(1e-9);
        }
        Self { dim, a_inv, b: vec![0.0; dim], w: vec![0.0; dim], n: 0 }
    }

    pub fn n(&self) -> u64 {
        self.n
    }

    /// Rank-one update with observation (x, y).
    pub fn update(&mut self, x: &[f64], y: f64) {
        assert_eq!(x.len(), self.dim);
        let d = self.dim;
        // v = A⁻¹ x
        let mut v = vec![0.0; d];
        for i in 0..d {
            let mut acc = 0.0;
            for j in 0..d {
                acc += self.a_inv[i * d + j] * x[j];
            }
            v[i] = acc;
        }
        let denom = 1.0 + dot(x, &v);
        // A⁻¹ ← A⁻¹ − v vᵀ / denom   (Sherman–Morrison)
        for i in 0..d {
            for j in 0..d {
                self.a_inv[i * d + j] -= v[i] * v[j] / denom;
            }
        }
        for i in 0..d {
            self.b[i] += x[i] * y;
        }
        // w = A⁻¹ b
        for i in 0..d {
            let mut acc = 0.0;
            for j in 0..d {
                acc += self.a_inv[i * d + j] * self.b[j];
            }
            self.w[i] = acc;
        }
        self.n += 1;
    }

    /// Point prediction wᵀx.
    pub fn predict(&self, x: &[f64]) -> f64 {
        dot(&self.w, x)
    }

    /// Predictive uncertainty sqrt(xᵀ A⁻¹ x) — Wrangler's confidence bound.
    pub fn uncertainty(&self, x: &[f64]) -> f64 {
        let d = self.dim;
        let mut acc = 0.0;
        for i in 0..d {
            let mut row = 0.0;
            for j in 0..d {
                row += self.a_inv[i * d + j] * x[j];
            }
            acc += x[i] * row;
        }
        acc.max(0.0).sqrt()
    }

    pub fn weights(&self) -> &[f64] {
        &self.w
    }
}

fn dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    #[test]
    fn recovers_linear_function() {
        let mut rng = Pcg::seeded(1);
        let mut m = OnlineLinReg::new(3, 1e-3);
        let w_true = [2.0, -1.0, 0.5];
        for _ in 0..500 {
            let x = [rng.range(-1.0, 1.0), rng.range(-1.0, 1.0), 1.0];
            let y = dot(&w_true, &x) + 0.01 * rng.normal();
            m.update(&x, y);
        }
        for (got, want) in m.weights().iter().zip(&w_true) {
            assert!((got - want).abs() < 0.05, "{got} vs {want}");
        }
    }

    #[test]
    fn uncertainty_shrinks_with_data() {
        let mut rng = Pcg::seeded(2);
        let mut m = OnlineLinReg::new(2, 1.0);
        let x = [1.0, 0.5];
        let before = m.uncertainty(&x);
        for _ in 0..100 {
            let xi = [rng.range(0.0, 2.0), rng.range(0.0, 1.0)];
            m.update(&xi, xi[0] + xi[1]);
        }
        let after = m.uncertainty(&x);
        assert!(after < 0.2 * before, "before {before} after {after}");
    }

    #[test]
    fn uncertainty_higher_off_distribution() {
        let mut rng = Pcg::seeded(3);
        let mut m = OnlineLinReg::new(2, 1.0);
        for _ in 0..200 {
            let xi = [rng.range(0.0, 1.0), 1.0];
            m.update(&xi, xi[0]);
        }
        let in_dist = m.uncertainty(&[0.5, 1.0]);
        let out_dist = m.uncertainty(&[10.0, 1.0]);
        assert!(out_dist > 5.0 * in_dist);
    }
}
