//! Classic-ML substrates used by the baseline techniques: ARIMA (RPPS),
//! online linear regression (Wrangler), and nonlinear least-squares curve
//! fitting (NearestFit).  All from scratch — no external crates.

pub mod arima;
pub mod curvefit;
pub mod linreg;

pub use arima::Arima;
pub use curvefit::PowerFit;
pub use linreg::OnlineLinReg;
