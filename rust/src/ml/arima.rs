//! ARIMA(p, d, q) forecaster — the substrate of the RPPS baseline [23],
//! which predicts future workload characteristics with ARIMA.
//!
//! Fitting: the series is differenced `d` times, AR coefficients are
//! estimated by solving the Yule–Walker equations (Levinson–Durbin), and
//! the MA part is approximated by fitting the AR residuals' innovations
//! (conditional least squares with a fixed-point pass).  That matches how
//! lightweight embedded ARIMA implementations behave and is plenty for the
//! short utilization windows RPPS uses.

/// ARIMA(p, d, q) model fit over a window.
#[derive(Clone, Debug)]
pub struct Arima {
    pub p: usize,
    pub d: usize,
    pub q: usize,
    ar: Vec<f64>,
    ma: Vec<f64>,
    mean: f64,
}

impl Arima {
    /// Fit on a series.  Returns None if the series is too short.
    pub fn fit(series: &[f64], p: usize, d: usize, q: usize) -> Option<Arima> {
        if series.len() < p + d + q + 3 {
            return None;
        }
        let diffed = difference(series, d);
        let mean = diffed.iter().sum::<f64>() / diffed.len() as f64;
        let centered: Vec<f64> = diffed.iter().map(|x| x - mean).collect();
        let ar = if p > 0 { yule_walker(&centered, p)? } else { Vec::new() };
        // Residuals of the AR fit.
        let mut resid = vec![0.0; centered.len()];
        for t in p..centered.len() {
            let mut pred = 0.0;
            for (j, &a) in ar.iter().enumerate() {
                pred += a * centered[t - 1 - j];
            }
            resid[t] = centered[t] - pred;
        }
        // MA: regress residual on its own lags (one CLS pass).
        let ma = if q > 0 { fit_ma(&resid[p..], q) } else { Vec::new() };
        Some(Arima { p, d, q, ar, ma, mean })
    }

    /// One-step-ahead forecast given the original (undifferenced) series.
    pub fn forecast(&self, series: &[f64]) -> f64 {
        let diffed = difference(series, self.d);
        let centered: Vec<f64> = diffed.iter().map(|x| x - self.mean).collect();
        let n = centered.len();
        let mut pred = 0.0;
        for (j, &a) in self.ar.iter().enumerate() {
            if n > j {
                pred += a * centered[n - 1 - j];
            }
        }
        // Approximate innovations by AR residuals for the MA terms.
        for (j, &m) in self.ma.iter().enumerate() {
            if n > j + self.p {
                let t = n - 1 - j;
                let mut ar_pred = 0.0;
                for (i, &a) in self.ar.iter().enumerate() {
                    if t > i {
                        ar_pred += a * centered[t - 1 - i];
                    }
                }
                pred += m * (centered[t] - ar_pred);
            }
        }
        let next_diff = pred + self.mean;
        undifference(series, self.d, next_diff)
    }
}

/// d-th order differencing.
fn difference(series: &[f64], d: usize) -> Vec<f64> {
    let mut cur = series.to_vec();
    for _ in 0..d {
        cur = cur.windows(2).map(|w| w[1] - w[0]).collect();
    }
    cur
}

/// Invert differencing for a one-step forecast.
fn undifference(series: &[f64], d: usize, next_diff: f64) -> f64 {
    // next value = next_diff + sum of the last values of each differencing
    // level; reconstruct by cumulative addition.
    let mut levels = Vec::with_capacity(d + 1);
    let mut cur = series.to_vec();
    levels.push(*cur.last().unwrap());
    for _ in 0..d {
        cur = cur.windows(2).map(|w| w[1] - w[0]).collect();
        if cur.is_empty() {
            break;
        }
        levels.push(*cur.last().unwrap());
    }
    // For d=0: forecast = next_diff; d=1: last + next_diff; d=2: …
    let mut val = next_diff;
    for lvl in levels.iter().take(d).rev() {
        val += lvl;
    }
    val
}

/// Levinson–Durbin solve of the Yule–Walker equations.
fn yule_walker(x: &[f64], p: usize) -> Option<Vec<f64>> {
    let n = x.len();
    if n <= p {
        return None;
    }
    let mut r = vec![0.0; p + 1];
    for (k, rk) in r.iter_mut().enumerate() {
        let mut acc = 0.0;
        for t in k..n {
            acc += x[t] * x[t - k];
        }
        *rk = acc / n as f64;
    }
    if r[0] <= 1e-12 {
        return Some(vec![0.0; p]); // constant series
    }
    let mut a = vec![0.0; p];
    let mut e = r[0];
    for k in 0..p {
        let mut acc = r[k + 1];
        for j in 0..k {
            acc -= a[j] * r[k - j];
        }
        let kappa = acc / e;
        a[k] = kappa;
        for j in 0..k / 2 + k % 2 {
            let tmp = a[j] - kappa * a[k - 1 - j];
            a[k - 1 - j] -= kappa * a[j];
            a[j] = tmp;
        }
        e *= 1.0 - kappa * kappa;
        if e <= 1e-12 {
            break;
        }
    }
    Some(a)
}

/// Least-squares fit of residual on its own lags (MA approximation).
fn fit_ma(resid: &[f64], q: usize) -> Vec<f64> {
    let n = resid.len();
    if n <= q + 1 {
        return vec![0.0; q];
    }
    let mut coef = vec![0.0; q];
    for (j, cj) in coef.iter_mut().enumerate() {
        let mut num = 0.0;
        let mut den = 0.0;
        for t in (j + 1)..n {
            num += resid[t] * resid[t - 1 - j];
            den += resid[t - 1 - j] * resid[t - 1 - j];
        }
        *cj = if den > 1e-12 { (num / den).clamp(-0.98, 0.98) } else { 0.0 };
    }
    coef
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    #[test]
    fn constant_series_forecasts_constant() {
        let xs = vec![5.0; 30];
        let m = Arima::fit(&xs, 2, 0, 1).unwrap();
        let f = m.forecast(&xs);
        assert!((f - 5.0).abs() < 1e-6, "{f}");
    }

    #[test]
    fn linear_trend_with_d1() {
        let xs: Vec<f64> = (0..40).map(|i| 2.0 * i as f64 + 1.0).collect();
        let m = Arima::fit(&xs, 1, 1, 0).unwrap();
        let f = m.forecast(&xs);
        assert!((f - 81.0).abs() < 0.5, "{f}"); // next = 2·40+1
    }

    #[test]
    fn ar1_recovers_coefficient() {
        let mut rng = Pcg::seeded(1);
        let phi = 0.7;
        let mut xs = vec![0.0];
        for _ in 0..3000 {
            let prev = *xs.last().unwrap();
            xs.push(phi * prev + rng.normal());
        }
        let m = Arima::fit(&xs, 1, 0, 0).unwrap();
        assert!((m.ar[0] - phi).abs() < 0.08, "ar {:?}", m.ar);
    }

    #[test]
    fn forecast_beats_naive_on_ar_series() {
        let mut rng = Pcg::seeded(2);
        let phi = 0.85;
        let mut xs = vec![0.0];
        for _ in 0..500 {
            let prev = *xs.last().unwrap();
            xs.push(phi * prev + rng.normal());
        }
        let mut err_arima = 0.0;
        let mut err_naive = 0.0;
        for t in 100..499 {
            let window = &xs[..t];
            if let Some(m) = Arima::fit(window, 2, 0, 1) {
                let f = m.forecast(window);
                err_arima += (f - xs[t]).powi(2);
                err_naive += (0.0 - xs[t]).powi(2); // mean-predictor baseline
            }
        }
        assert!(err_arima < 0.7 * err_naive, "arima {err_arima} naive {err_naive}");
    }

    #[test]
    fn too_short_series_returns_none() {
        assert!(Arima::fit(&[1.0, 2.0], 2, 1, 1).is_none());
    }
}
