//! Nonlinear least-squares fit of `y = a + b·x^c` — the progress-profile
//! model of the NearestFit baseline [6] (x = task input size, y = time).
//!
//! Gauss–Newton with a log-space initialization for `c` and damped steps.

/// Fitted power-law profile.
#[derive(Clone, Copy, Debug)]
pub struct PowerFit {
    pub a: f64,
    pub b: f64,
    pub c: f64,
}

impl PowerFit {
    /// Fit `y = a + b·x^c` over samples (x > 0).
    pub fn fit(xs: &[f64], ys: &[f64]) -> Option<PowerFit> {
        if xs.len() != ys.len() || xs.len() < 3 || xs.iter().any(|&x| x <= 0.0) {
            return None;
        }
        // Initialize: a ≈ min(y) · 0.9, slope in log space for b, c.
        let ymin = ys.iter().cloned().fold(f64::INFINITY, f64::min);
        let mut a = 0.9 * ymin;
        let (mut b, mut c) = log_init(xs, ys, a).unwrap_or((1.0, 1.0));
        for _ in 0..60 {
            // Residuals r_i = y_i − (a + b x^c); Jacobian columns:
            // ∂/∂a = 1, ∂/∂b = x^c, ∂/∂c = b x^c ln x.
            let mut jtj = [[0.0f64; 3]; 3];
            let mut jtr = [0.0f64; 3];
            for (&x, &y) in xs.iter().zip(ys) {
                let xc = x.powf(c);
                let j = [1.0, xc, b * xc * x.ln()];
                let r = y - (a + b * xc);
                for p in 0..3 {
                    jtr[p] += j[p] * r;
                    for q in 0..3 {
                        jtj[p][q] += j[p] * j[q];
                    }
                }
            }
            // Levenberg damping.
            for (p, row) in jtj.iter_mut().enumerate() {
                row[p] += 1e-6 + 1e-3 * row[p];
            }
            let delta = solve3(&jtj, &jtr)?;
            a += delta[0];
            b += delta[1];
            c = (c + delta[2]).clamp(-3.0, 3.0);
            if delta.iter().all(|d| d.abs() < 1e-10) {
                break;
            }
        }
        (a.is_finite() && b.is_finite() && c.is_finite()).then_some(PowerFit { a, b, c })
    }

    pub fn predict(&self, x: f64) -> f64 {
        self.a + self.b * x.powf(self.c)
    }

    /// Root-mean-square error over a sample.
    pub fn rmse(&self, xs: &[f64], ys: &[f64]) -> f64 {
        let sse: f64 = xs
            .iter()
            .zip(ys)
            .map(|(&x, &y)| {
                let e = y - self.predict(x);
                e * e
            })
            .sum();
        (sse / xs.len() as f64).sqrt()
    }
}

fn log_init(xs: &[f64], ys: &[f64], a: f64) -> Option<(f64, f64)> {
    // log(y − a) = log b + c log x  →  least squares on logs.
    let mut sx = 0.0;
    let mut sy = 0.0;
    let mut sxx = 0.0;
    let mut sxy = 0.0;
    let mut n = 0.0;
    for (&x, &y) in xs.iter().zip(ys) {
        let r = y - a;
        if r <= 0.0 {
            continue;
        }
        let lx = x.ln();
        let ly = r.ln();
        sx += lx;
        sy += ly;
        sxx += lx * lx;
        sxy += lx * ly;
        n += 1.0;
    }
    if n < 2.0 {
        return None;
    }
    let denom = n * sxx - sx * sx;
    if denom.abs() < 1e-12 {
        return None;
    }
    let c = (n * sxy - sx * sy) / denom;
    let logb = (sy - c * sx) / n;
    Some((logb.exp(), c))
}

/// Solve a 3×3 linear system by Gaussian elimination with partial pivoting.
fn solve3(m: &[[f64; 3]; 3], rhs: &[f64; 3]) -> Option<[f64; 3]> {
    let mut a = *m;
    let mut b = *rhs;
    for col in 0..3 {
        let piv = (col..3).max_by(|&i, &j| a[i][col].abs().partial_cmp(&a[j][col].abs()).unwrap())?;
        if a[piv][col].abs() < 1e-14 {
            return None;
        }
        a.swap(col, piv);
        b.swap(col, piv);
        for row in col + 1..3 {
            let f = a[row][col] / a[col][col];
            for k in col..3 {
                a[row][k] -= f * a[col][k];
            }
            b[row] -= f * b[col];
        }
    }
    let mut x = [0.0; 3];
    for col in (0..3).rev() {
        let mut acc = b[col];
        for k in col + 1..3 {
            acc -= a[col][k] * x[k];
        }
        x[col] = acc / a[col][col];
    }
    Some(x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    #[test]
    fn exact_recovery_noiseless() {
        let (a, b, c) = (2.0, 0.5, 1.3);
        let xs: Vec<f64> = (1..40).map(|i| i as f64 * 0.5).collect();
        let ys: Vec<f64> = xs.iter().map(|&x| a + b * x.powf(c)).collect();
        let fit = PowerFit::fit(&xs, &ys).unwrap();
        assert!((fit.a - a).abs() < 0.05, "a {}", fit.a);
        assert!((fit.b - b).abs() < 0.05, "b {}", fit.b);
        assert!((fit.c - c).abs() < 0.05, "c {}", fit.c);
    }

    #[test]
    fn noisy_recovery_close() {
        let mut rng = Pcg::seeded(4);
        let (a, b, c) = (1.0, 2.0, 0.7);
        let xs: Vec<f64> = (1..200).map(|i| i as f64 * 0.1).collect();
        let ys: Vec<f64> =
            xs.iter().map(|&x| a + b * x.powf(c) + 0.05 * rng.normal()).collect();
        let fit = PowerFit::fit(&xs, &ys).unwrap();
        assert!(fit.rmse(&xs, &ys) < 0.1);
        assert!((fit.c - c).abs() < 0.1, "c {}", fit.c);
    }

    #[test]
    fn rejects_degenerate_input() {
        assert!(PowerFit::fit(&[1.0, 2.0], &[1.0, 2.0]).is_none());
        assert!(PowerFit::fit(&[0.0, 1.0, 2.0], &[1.0, 2.0, 3.0]).is_none());
    }

    #[test]
    fn linear_special_case() {
        // c = 1 reduces to a line.
        let xs: Vec<f64> = (1..30).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|&x| 3.0 + 2.0 * x).collect();
        let fit = PowerFit::fit(&xs, &ys).unwrap();
        assert!((fit.predict(50.0) - 103.0).abs() < 1.0);
    }
}
