//! Sim observability: structured event trace + phase profiler (DESIGN.md
//! §10).  Not to be confused with `crate::trace`, the *workload* traces
//! (PlanetLab / generative); this module records what the simulator *did*.
//!
//! Three pieces:
//!
//! * [`Event`] / [`TraceSink`] — an append-only stream of every task
//!   lifecycle transition (admit/start/complete/kill/hold/clone), scored
//!   predictions (E_S), mitigation actions, injected faults and
//!   per-interval resource snapshots, recorded by `World` (state
//!   transitions) and `Simulation` (decisions).  The sink is a no-op
//!   unless explicitly enabled — one predicted branch per site, event
//!   construction skipped — and with the `sim-trace` cargo feature off it
//!   compiles to a zero-sized type (the compile-time-checked no-op path;
//!   bench floors are measured with the sink `Off`).
//! * [`PhaseProfile`] — wall-time attribution of each interval to
//!   advance / arrivals / placement / predict / mitigate / metrics,
//!   accumulated in integer nanoseconds.  Fig. 10's manager overhead is
//!   *defined* as the predict+mitigate counters (one shared definition;
//!   see `RunMetrics::manager_overhead_s`).
//! * [`replay`] — the keystone invariant: a standalone reducer that
//!   re-derives `RunMetrics` from the event stream alone, bit-identical
//!   to the live run (`rust/tests/trace_replay.rs`), making a recorded
//!   trace a verified ground-truth artifact instead of best-effort
//!   logging.
//!
//! Serialization is JSONL (one compact object per line, lossless f64
//! round-trip, replayable) or CSV (flat lossy view for spreadsheets),
//! via `util::json` — no external dependencies.

use crate::sim::metrics::{IntervalMetrics, RunMetrics};
use crate::sim::types::{EntityId, HostId, JobId, TaskId, VmId};
use crate::util::json::{self, Json};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::{BTreeSet, HashMap};
use std::io::Write;
use std::path::Path;
use std::time::Duration;

// ===================================================================== events

/// Task state at admission (for set recounting; engine-created tasks are
/// always `Pending`, tests may admit in other states).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LifeState {
    Pending,
    Running,
    Held,
    Done,
}

/// Mitigation strategy tag (mirrors `mitigation::Action`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MitigationKind {
    Speculate,
    Rerun,
    Hold,
}

/// An injected fault, with its resolved target.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultEvent {
    Host { host: HostId, until: f64 },
    Cloudlet { vm: VmId, task: Option<TaskId> },
    VmCreation { vm: VmId, ready_at: f64 },
}

/// One trace record.  World-level events are state transitions (recorded
/// at the registry choke points); engine-level events are decisions and
/// metric facts.  Every event carries the simulation time `t`.
#[derive(Clone, Debug, PartialEq)]
pub enum Event {
    /// Run header (first event when tracing a `Simulation`).
    Meta { seed: u64, n_intervals: usize, interval_s: f64, technique: String, scheduler: String },
    // ----------------------------------------------- world: task lifecycle
    TaskAdmit {
        t: f64,
        task: TaskId,
        job: JobId,
        submit_t: f64,
        /// `Some(orig)` marks a speculative clone of `orig`.
        speculative_of: Option<TaskId>,
        state: LifeState,
    },
    TaskStart { t: f64, task: TaskId, vm: VmId, slowdown: f64 },
    /// Physical completion (this execution finished).
    TaskComplete { t: f64, task: TaskId },
    /// Logical completion via a clone (this execution did not finish).
    TaskSuperseded { t: f64, task: TaskId },
    TaskKill { t: f64, task: TaskId },
    TaskReset { t: f64, task: TaskId, penalty_s: f64 },
    TaskHold { t: f64, task: TaskId, until: f64 },
    TaskRelease { t: f64, task: TaskId },
    // ----------------------------------------------- world: job lifecycle
    JobAdmit { t: f64, job: JobId, tasks: Vec<TaskId>, deadline_driven: bool, sla_weight: f64 },
    JobSla { t: f64, job: JobId, deadline: f64 },
    JobDone { t: f64, job: JobId },
    // ------------------------------------------------- engine: metric facts
    /// An original task's result became available (clone- or self-finish):
    /// the record behind exec/restart/completion times and the confusion
    /// counts (`mitigated` = predicted straggler, `straggler` = ground
    /// truth).
    TaskResult { t: f64, task: TaskId, job: JobId, mitigated: bool, straggler: bool },
    /// Job finished: the technique's predicted straggler count E_S scored
    /// against the realized count (Eq. 14 MAPE; SLA via `JobSla`).
    JobScore { t: f64, job: JobId, predicted_es: f64, actual_stragglers: usize },
    // -------------------------------------------------- engine: decisions
    Mitigate {
        t: f64,
        task: TaskId,
        kind: MitigationKind,
        /// Whether the action took effect (a stale target is skipped).
        applied: bool,
        /// The task's first start time, when it had one (delay metric).
        started: Option<f64>,
    },
    /// Manager vetoed a placement (Wrangler); the task stays pending.
    Veto { t: f64, task: TaskId, vm: VmId },
    Fault { t: f64, fault: FaultEvent },
    /// Per-interval resource snapshot (main horizon only).
    Interval { index: usize, snapshot: IntervalMetrics },
}

impl Event {
    /// Simulation time of the event (Meta reports 0).
    pub fn t(&self) -> f64 {
        match self {
            Event::Meta { .. } => 0.0,
            Event::TaskAdmit { t, .. }
            | Event::TaskStart { t, .. }
            | Event::TaskComplete { t, .. }
            | Event::TaskSuperseded { t, .. }
            | Event::TaskKill { t, .. }
            | Event::TaskReset { t, .. }
            | Event::TaskHold { t, .. }
            | Event::TaskRelease { t, .. }
            | Event::JobAdmit { t, .. }
            | Event::JobSla { t, .. }
            | Event::JobDone { t, .. }
            | Event::TaskResult { t, .. }
            | Event::JobScore { t, .. }
            | Event::Mitigate { t, .. }
            | Event::Veto { t, .. }
            | Event::Fault { t, .. } => *t,
            Event::Interval { snapshot, .. } => snapshot.t,
        }
    }

    /// Schema tag (the JSONL `ev` field / CSV `event` column).
    pub fn tag(&self) -> &'static str {
        match self {
            Event::Meta { .. } => "meta",
            Event::TaskAdmit { .. } => "task_admit",
            Event::TaskStart { .. } => "task_start",
            Event::TaskComplete { .. } => "task_complete",
            Event::TaskSuperseded { .. } => "task_superseded",
            Event::TaskKill { .. } => "task_kill",
            Event::TaskReset { .. } => "task_reset",
            Event::TaskHold { .. } => "task_hold",
            Event::TaskRelease { .. } => "task_release",
            Event::JobAdmit { .. } => "job_admit",
            Event::JobSla { .. } => "job_sla",
            Event::JobDone { .. } => "job_done",
            Event::TaskResult { .. } => "task_result",
            Event::JobScore { .. } => "job_score",
            Event::Mitigate { .. } => "mitigate",
            Event::Veto { .. } => "veto",
            Event::Fault { .. } => "fault",
            Event::Interval { .. } => "interval",
        }
    }
}

// ============================================================== serialization

fn num(n: usize) -> Json {
    Json::Num(n as f64)
}

/// Entity ids serialize as their bare arena index — the JSONL schema is
/// unchanged from the `usize`-alias era.
fn id<I: EntityId>(i: I) -> Json {
    Json::Num(i.raw() as f64)
}

fn opt_id<I: EntityId>(v: Option<I>) -> Json {
    match v {
        Some(i) => id(i),
        None => Json::Null,
    }
}

fn life_str(s: LifeState) -> &'static str {
    match s {
        LifeState::Pending => "pending",
        LifeState::Running => "running",
        LifeState::Held => "held",
        LifeState::Done => "done",
    }
}

fn life_parse(s: &str) -> Result<LifeState> {
    Ok(match s {
        "pending" => LifeState::Pending,
        "running" => LifeState::Running,
        "held" => LifeState::Held,
        "done" => LifeState::Done,
        other => bail!("unknown life state {other:?}"),
    })
}

fn kind_str(k: MitigationKind) -> &'static str {
    match k {
        MitigationKind::Speculate => "speculate",
        MitigationKind::Rerun => "rerun",
        MitigationKind::Hold => "hold",
    }
}

fn kind_parse(s: &str) -> Result<MitigationKind> {
    Ok(match s {
        "speculate" => MitigationKind::Speculate,
        "rerun" => MitigationKind::Rerun,
        "hold" => MitigationKind::Hold,
        other => bail!("unknown mitigation kind {other:?}"),
    })
}

fn snapshot_json(m: &IntervalMetrics) -> Json {
    Json::obj(vec![
        ("t", Json::Num(m.t)),
        ("energy_kwh", Json::Num(m.energy_kwh)),
        ("cpu", Json::Num(m.cpu_util)),
        ("ram", Json::Num(m.ram_util)),
        ("disk", Json::Num(m.disk_util)),
        ("net", Json::Num(m.net_util)),
        ("contention", Json::Num(m.contention)),
        ("active_tasks", num(m.active_tasks)),
        ("hosts_down", num(m.hosts_down)),
    ])
}

fn snapshot_parse(v: &Json) -> Result<IntervalMetrics> {
    Ok(IntervalMetrics {
        t: v.req_f64("t")?,
        energy_kwh: v.req_f64("energy_kwh")?,
        cpu_util: v.req_f64("cpu")?,
        ram_util: v.req_f64("ram")?,
        disk_util: v.req_f64("disk")?,
        net_util: v.req_f64("net")?,
        contention: v.req_f64("contention")?,
        active_tasks: v.req_usize("active_tasks")?,
        hosts_down: v.req_usize("hosts_down")?,
    })
}

impl Event {
    /// Tagged JSON object (one JSONL line when dumped).
    pub fn to_json(&self) -> Json {
        let mut fields: Vec<(&str, Json)> = vec![("ev", Json::str(self.tag()))];
        match self {
            Event::Meta { seed, n_intervals, interval_s, technique, scheduler } => {
                fields.push(("seed", num(*seed as usize)));
                fields.push(("n_intervals", num(*n_intervals)));
                fields.push(("interval_s", Json::Num(*interval_s)));
                fields.push(("technique", Json::str(technique.clone())));
                fields.push(("scheduler", Json::str(scheduler.clone())));
            }
            Event::TaskAdmit { t, task, job, submit_t, speculative_of, state } => {
                fields.push(("t", Json::Num(*t)));
                fields.push(("task", id(*task)));
                fields.push(("job", id(*job)));
                fields.push(("submit_t", Json::Num(*submit_t)));
                fields.push(("clone_of", opt_id(*speculative_of)));
                fields.push(("state", Json::str(life_str(*state))));
            }
            Event::TaskStart { t, task, vm, slowdown } => {
                fields.push(("t", Json::Num(*t)));
                fields.push(("task", id(*task)));
                fields.push(("vm", id(*vm)));
                fields.push(("slowdown", Json::Num(*slowdown)));
            }
            Event::TaskComplete { t, task }
            | Event::TaskSuperseded { t, task }
            | Event::TaskKill { t, task }
            | Event::TaskRelease { t, task } => {
                fields.push(("t", Json::Num(*t)));
                fields.push(("task", id(*task)));
            }
            Event::TaskReset { t, task, penalty_s } => {
                fields.push(("t", Json::Num(*t)));
                fields.push(("task", id(*task)));
                fields.push(("penalty_s", Json::Num(*penalty_s)));
            }
            Event::TaskHold { t, task, until } => {
                fields.push(("t", Json::Num(*t)));
                fields.push(("task", id(*task)));
                fields.push(("until", Json::Num(*until)));
            }
            Event::JobAdmit { t, job, tasks, deadline_driven, sla_weight } => {
                fields.push(("t", Json::Num(*t)));
                fields.push(("job", id(*job)));
                fields.push(("tasks", Json::Arr(tasks.iter().map(|&x| id(x)).collect())));
                fields.push(("deadline_driven", Json::Bool(*deadline_driven)));
                fields.push(("sla_weight", Json::Num(*sla_weight)));
            }
            Event::JobSla { t, job, deadline } => {
                fields.push(("t", Json::Num(*t)));
                fields.push(("job", id(*job)));
                fields.push(("deadline", Json::Num(*deadline)));
            }
            Event::JobDone { t, job } => {
                fields.push(("t", Json::Num(*t)));
                fields.push(("job", id(*job)));
            }
            Event::TaskResult { t, task, job, mitigated, straggler } => {
                fields.push(("t", Json::Num(*t)));
                fields.push(("task", id(*task)));
                fields.push(("job", id(*job)));
                fields.push(("mitigated", Json::Bool(*mitigated)));
                fields.push(("straggler", Json::Bool(*straggler)));
            }
            Event::JobScore { t, job, predicted_es, actual_stragglers } => {
                fields.push(("t", Json::Num(*t)));
                fields.push(("job", id(*job)));
                fields.push(("predicted_es", Json::Num(*predicted_es)));
                fields.push(("actual", num(*actual_stragglers)));
            }
            Event::Mitigate { t, task, kind, applied, started } => {
                fields.push(("t", Json::Num(*t)));
                fields.push(("task", id(*task)));
                fields.push(("kind", Json::str(kind_str(*kind))));
                fields.push(("applied", Json::Bool(*applied)));
                fields.push((
                    "started",
                    match started {
                        Some(s) => Json::Num(*s),
                        None => Json::Null,
                    },
                ));
            }
            Event::Veto { t, task, vm } => {
                fields.push(("t", Json::Num(*t)));
                fields.push(("task", id(*task)));
                fields.push(("vm", id(*vm)));
            }
            Event::Fault { t, fault } => {
                fields.push(("t", Json::Num(*t)));
                match fault {
                    FaultEvent::Host { host, until } => {
                        fields.push(("kind", Json::str("host")));
                        fields.push(("host", id(*host)));
                        fields.push(("until", Json::Num(*until)));
                    }
                    FaultEvent::Cloudlet { vm, task } => {
                        fields.push(("kind", Json::str("cloudlet")));
                        fields.push(("vm", id(*vm)));
                        fields.push(("task", opt_id(*task)));
                    }
                    FaultEvent::VmCreation { vm, ready_at } => {
                        fields.push(("kind", Json::str("vm_creation")));
                        fields.push(("vm", id(*vm)));
                        fields.push(("ready_at", Json::Num(*ready_at)));
                    }
                }
            }
            Event::Interval { index, snapshot } => {
                fields.push(("index", num(*index)));
                fields.push(("snapshot", snapshot_json(snapshot)));
            }
        }
        Json::obj(fields)
    }

    /// Inverse of `to_json` (exact f64 round-trip: the serializer prints
    /// shortest-representation floats).
    pub fn from_json(v: &Json) -> Result<Event> {
        let tag = v.req_str("ev")?;
        let t = || v.req_f64("t");
        let task = || v.req_usize("task").map(TaskId::new);
        let job = || v.req_usize("job").map(JobId::new);
        Ok(match tag {
            "meta" => Event::Meta {
                seed: v.req_f64("seed")? as u64,
                n_intervals: v.req_usize("n_intervals")?,
                interval_s: v.req_f64("interval_s")?,
                technique: v.req_str("technique")?.to_string(),
                scheduler: v.req_str("scheduler")?.to_string(),
            },
            "task_admit" => Event::TaskAdmit {
                t: t()?,
                task: task()?,
                job: job()?,
                submit_t: v.req_f64("submit_t")?,
                speculative_of: v
                    .get("clone_of")
                    .and_then(Json::as_f64)
                    .map(|f| TaskId::new(f as usize)),
                state: life_parse(v.req_str("state")?)?,
            },
            "task_start" => Event::TaskStart {
                t: t()?,
                task: task()?,
                vm: VmId::new(v.req_usize("vm")?),
                slowdown: v.req_f64("slowdown")?,
            },
            "task_complete" => Event::TaskComplete { t: t()?, task: task()? },
            "task_superseded" => Event::TaskSuperseded { t: t()?, task: task()? },
            "task_kill" => Event::TaskKill { t: t()?, task: task()? },
            "task_release" => Event::TaskRelease { t: t()?, task: task()? },
            "task_reset" => Event::TaskReset {
                t: t()?,
                task: task()?,
                penalty_s: v.req_f64("penalty_s")?,
            },
            "task_hold" => Event::TaskHold { t: t()?, task: task()?, until: v.req_f64("until")? },
            "job_admit" => Event::JobAdmit {
                t: t()?,
                job: job()?,
                tasks: v
                    .req_arr("tasks")?
                    .iter()
                    .map(|x| {
                        x.as_usize().map(TaskId::new).ok_or_else(|| anyhow!("non-numeric task id"))
                    })
                    .collect::<Result<_>>()?,
                deadline_driven: v
                    .get("deadline_driven")
                    .and_then(Json::as_bool)
                    .ok_or_else(|| anyhow!("missing deadline_driven"))?,
                sla_weight: v.req_f64("sla_weight")?,
            },
            "job_sla" => Event::JobSla { t: t()?, job: job()?, deadline: v.req_f64("deadline")? },
            "job_done" => Event::JobDone { t: t()?, job: job()? },
            "task_result" => Event::TaskResult {
                t: t()?,
                task: task()?,
                job: job()?,
                mitigated: v
                    .get("mitigated")
                    .and_then(Json::as_bool)
                    .ok_or_else(|| anyhow!("missing mitigated"))?,
                straggler: v
                    .get("straggler")
                    .and_then(Json::as_bool)
                    .ok_or_else(|| anyhow!("missing straggler"))?,
            },
            "job_score" => Event::JobScore {
                t: t()?,
                job: job()?,
                predicted_es: v.req_f64("predicted_es")?,
                actual_stragglers: v.req_usize("actual")?,
            },
            "mitigate" => Event::Mitigate {
                t: t()?,
                task: task()?,
                kind: kind_parse(v.req_str("kind")?)?,
                applied: v
                    .get("applied")
                    .and_then(Json::as_bool)
                    .ok_or_else(|| anyhow!("missing applied"))?,
                started: v.get("started").and_then(Json::as_f64),
            },
            "veto" => Event::Veto { t: t()?, task: task()?, vm: VmId::new(v.req_usize("vm")?) },
            "fault" => Event::Fault {
                t: t()?,
                fault: match v.req_str("kind")? {
                    "host" => FaultEvent::Host {
                        host: HostId::new(v.req_usize("host")?),
                        until: v.req_f64("until")?,
                    },
                    "cloudlet" => FaultEvent::Cloudlet {
                        vm: VmId::new(v.req_usize("vm")?),
                        task: v.get("task").and_then(Json::as_f64).map(|f| TaskId::new(f as usize)),
                    },
                    "vm_creation" => FaultEvent::VmCreation {
                        vm: VmId::new(v.req_usize("vm")?),
                        ready_at: v.req_f64("ready_at")?,
                    },
                    other => bail!("unknown fault kind {other:?}"),
                },
            },
            "interval" => Event::Interval {
                index: v.req_usize("index")?,
                snapshot: snapshot_parse(
                    v.get("snapshot").ok_or_else(|| anyhow!("missing snapshot"))?,
                )?,
            },
            other => bail!("unknown event tag {other:?}"),
        })
    }

    /// CSV header matching `csv_cells` (flat, lossy view — JSONL is the
    /// replayable ground-truth format).
    pub const CSV_HEADER: &'static str = "event,t,task,job,vm,x,y,tag";

    /// Flattened CSV row: per-variant numeric payloads land in `x`/`y`,
    /// categorical payloads in `tag`; absent columns stay empty.
    pub fn csv_cells(&self) -> [String; 8] {
        let f = |v: f64| format!("{v}");
        let u = |v: usize| v.to_string();
        let mut c: [String; 8] = Default::default();
        c[0] = self.tag().to_string();
        c[1] = f(self.t());
        match self {
            Event::Meta { seed, n_intervals, technique, scheduler, .. } => {
                c[5] = u(*seed as usize);
                c[6] = u(*n_intervals);
                c[7] = format!("{technique}/{scheduler}");
            }
            Event::TaskAdmit { task, job, submit_t, speculative_of, state, .. } => {
                c[2] = u(task.raw());
                c[3] = u(job.raw());
                c[5] = f(*submit_t);
                if let Some(orig) = speculative_of {
                    c[6] = u(orig.raw());
                }
                c[7] = life_str(*state).to_string();
            }
            Event::TaskStart { task, vm, slowdown, .. } => {
                c[2] = u(task.raw());
                c[4] = u(vm.raw());
                c[5] = f(*slowdown);
            }
            Event::TaskComplete { task, .. }
            | Event::TaskSuperseded { task, .. }
            | Event::TaskKill { task, .. }
            | Event::TaskRelease { task, .. } => c[2] = u(task.raw()),
            Event::TaskReset { task, penalty_s, .. } => {
                c[2] = u(task.raw());
                c[5] = f(*penalty_s);
            }
            Event::TaskHold { task, until, .. } => {
                c[2] = u(task.raw());
                c[5] = f(*until);
            }
            Event::JobAdmit { job, tasks, sla_weight, .. } => {
                c[3] = u(job.raw());
                c[5] = f(*sla_weight);
                c[6] = u(tasks.len());
            }
            Event::JobSla { job, deadline, .. } => {
                c[3] = u(job.raw());
                c[5] = f(*deadline);
            }
            Event::JobDone { job, .. } => c[3] = u(job.raw()),
            Event::TaskResult { task, job, mitigated, straggler, .. } => {
                c[2] = u(task.raw());
                c[3] = u(job.raw());
                c[5] = u(*mitigated as usize);
                c[6] = u(*straggler as usize);
            }
            Event::JobScore { job, predicted_es, actual_stragglers, .. } => {
                c[3] = u(job.raw());
                c[5] = f(*predicted_es);
                c[6] = u(*actual_stragglers);
            }
            Event::Mitigate { task, kind, applied, started, .. } => {
                c[2] = u(task.raw());
                c[5] = u(*applied as usize);
                if let Some(s) = started {
                    c[6] = f(*s);
                }
                c[7] = kind_str(*kind).to_string();
            }
            Event::Veto { task, vm, .. } => {
                c[2] = u(task.raw());
                c[4] = u(vm.raw());
            }
            Event::Fault { fault, .. } => match fault {
                FaultEvent::Host { host, until } => {
                    c[5] = u(host.raw());
                    c[6] = f(*until);
                    c[7] = "host".to_string();
                }
                FaultEvent::Cloudlet { vm, task } => {
                    c[4] = u(vm.raw());
                    if let Some(tk) = task {
                        c[2] = u(tk.raw());
                    }
                    c[7] = "cloudlet".to_string();
                }
                FaultEvent::VmCreation { vm, ready_at } => {
                    c[4] = u(vm.raw());
                    c[5] = f(*ready_at);
                    c[7] = "vm_creation".to_string();
                }
            },
            Event::Interval { index, snapshot } => {
                c[5] = u(*index);
                c[6] = f(snapshot.energy_kwh);
            }
        }
        c
    }
}

// ------------------------------------------------- metrics round-trip

fn f64_field_vec(v: &Json, key: &str) -> Result<Vec<f64>> {
    v.req_arr(key)?
        .iter()
        .map(|x| x.as_f64().ok_or_else(|| anyhow!("non-numeric element in {key:?}")))
        .collect()
}

impl PhaseProfile {
    /// Exact (nanosecond-integer) serialization of the profiler counters.
    /// Unlike [`PhaseProfile::to_json`] — a derived human summary — this
    /// form round-trips bit-identically through
    /// [`PhaseProfile::from_json_exact`], which the coordinator's results
    /// journal relies on so a resumed batch reproduces journaled cells
    /// exactly, profiler included.
    pub fn to_json_exact(&self) -> Json {
        let nums = |xs: &[u64]| Json::Arr(xs.iter().map(|&n| Json::Num(n as f64)).collect());
        Json::obj(vec![
            ("nanos", nums(&self.nanos)),
            ("calls", nums(&self.calls)),
            ("predict_nanos", nums(&self.predict_nanos)),
            ("predict_calls", Json::Num(self.predict_calls as f64)),
        ])
    }

    /// Inverse of [`PhaseProfile::to_json_exact`].
    pub fn from_json_exact(v: &Json) -> Result<PhaseProfile> {
        fn arr<const N: usize>(v: &Json, key: &str) -> Result<[u64; N]> {
            let xs = f64_field_vec(v, key)?;
            if xs.len() != N {
                bail!("{key:?}: expected {N} entries, got {}", xs.len());
            }
            let mut out = [0u64; N];
            for (o, x) in out.iter_mut().zip(xs) {
                *o = x as u64;
            }
            Ok(out)
        }
        Ok(PhaseProfile {
            nanos: arr::<6>(v, "nanos")?,
            calls: arr::<6>(v, "calls")?,
            predict_nanos: arr::<3>(v, "predict_nanos")?,
            predict_calls: v.req_f64("predict_calls")? as u64,
        })
    }
}

/// Serialize a whole [`RunMetrics`] losslessly (every deterministic field
/// bit-exact via shortest-representation floats, plus the exact profiler
/// counters).  This is the payload of one coordinator journal record:
/// `metrics_from_json(&metrics_to_json(&m))` satisfies
/// `m.diff_deterministic(..) == None` *and* reproduces `m.profile`, so a
/// resumed experiment batch is indistinguishable from an uninterrupted
/// one.
pub fn metrics_to_json(m: &RunMetrics) -> Json {
    Json::obj(vec![
        ("intervals", Json::Arr(m.intervals.iter().map(snapshot_json).collect())),
        ("exec_times", Json::arr_f64(&m.exec_times)),
        ("restart_times", Json::arr_f64(&m.restart_times)),
        ("completion_times", Json::arr_f64(&m.completion_times)),
        ("sla_violated_weight", Json::Num(m.sla_violated_weight)),
        ("sla_total_weight", Json::Num(m.sla_total_weight)),
        (
            "straggler_pred",
            Json::Arr(
                m.straggler_pred
                    .iter()
                    .map(|&(p, a)| Json::Arr(vec![Json::Num(p), Json::Num(a)]))
                    .collect(),
            ),
        ),
        (
            "confusion",
            Json::obj(vec![
                ("tp", Json::Num(m.confusion.tp as f64)),
                ("fp", Json::Num(m.confusion.fp as f64)),
                ("fn", Json::Num(m.confusion.fn_ as f64)),
                ("tn", Json::Num(m.confusion.tn as f64)),
            ]),
        ),
        ("profile", m.profile.to_json_exact()),
        ("mitigation_delays", Json::arr_f64(&m.mitigation_delays)),
        ("speculations", Json::Num(m.speculations as f64)),
        ("reruns", Json::Num(m.reruns as f64)),
        ("jobs_done", Json::Num(m.jobs_done as f64)),
        ("tasks_done", Json::Num(m.tasks_done as f64)),
    ])
}

/// Inverse of [`metrics_to_json`].
pub fn metrics_from_json(v: &Json) -> Result<RunMetrics> {
    let confusion = v.get("confusion").ok_or_else(|| anyhow!("missing confusion"))?;
    Ok(RunMetrics {
        intervals: v
            .req_arr("intervals")?
            .iter()
            .map(snapshot_parse)
            .collect::<Result<_>>()?,
        exec_times: f64_field_vec(v, "exec_times")?,
        restart_times: f64_field_vec(v, "restart_times")?,
        completion_times: f64_field_vec(v, "completion_times")?,
        sla_violated_weight: v.req_f64("sla_violated_weight")?,
        sla_total_weight: v.req_f64("sla_total_weight")?,
        straggler_pred: v
            .req_arr("straggler_pred")?
            .iter()
            .map(|pair| {
                let xs = pair.as_arr().ok_or_else(|| anyhow!("straggler_pred: non-array pair"))?;
                match xs {
                    [p, a] => Ok((
                        p.as_f64().ok_or_else(|| anyhow!("straggler_pred: non-numeric"))?,
                        a.as_f64().ok_or_else(|| anyhow!("straggler_pred: non-numeric"))?,
                    )),
                    _ => bail!("straggler_pred: expected [pred, actual]"),
                }
            })
            .collect::<Result<_>>()?,
        confusion: crate::util::stats::Confusion {
            tp: confusion.req_f64("tp")? as u64,
            fp: confusion.req_f64("fp")? as u64,
            fn_: confusion.req_f64("fn")? as u64,
            tn: confusion.req_f64("tn")? as u64,
        },
        profile: PhaseProfile::from_json_exact(
            v.get("profile").ok_or_else(|| anyhow!("missing profile"))?,
        )?,
        mitigation_delays: f64_field_vec(v, "mitigation_delays")?,
        speculations: v.req_f64("speculations")? as u64,
        reruns: v.req_f64("reruns")? as u64,
        jobs_done: v.req_usize("jobs_done")?,
        tasks_done: v.req_usize("tasks_done")?,
    })
}

/// Serialize events as JSONL into a writer.
pub fn write_jsonl(events: &[Event], w: &mut impl Write) -> std::io::Result<()> {
    for e in events {
        writeln!(w, "{}", e.to_json().dump())?;
    }
    Ok(())
}

/// Parse a JSONL event stream (blank lines skipped).
pub fn read_jsonl(text: &str) -> Result<Vec<Event>> {
    text.lines()
        .map(str::trim)
        .filter(|l| !l.is_empty())
        .enumerate()
        .map(|(i, line)| {
            let v = json::parse(line).with_context(|| format!("trace line {}", i + 1))?;
            Event::from_json(&v).with_context(|| format!("trace line {}", i + 1))
        })
        .collect()
}

/// Load a JSONL trace file.
pub fn load_jsonl(path: impl AsRef<Path>) -> Result<Vec<Event>> {
    let path = path.as_ref();
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading trace {}", path.display()))?;
    read_jsonl(&text)
}

// ======================================================================= sink

/// Append-only event sink.  The default (`Off`) costs one branch per
/// instrumentation site — the event-construction closure is never
/// invoked.  With the `sim-trace` feature disabled the sink is a
/// zero-sized no-op (checked by `cargo check --no-default-features`).
#[derive(Default)]
pub struct TraceSink {
    #[cfg(feature = "sim-trace")]
    inner: Inner,
}

#[cfg(feature = "sim-trace")]
#[derive(Default)]
enum Inner {
    #[default]
    Off,
    Mem(Vec<Event>),
    File {
        w: std::io::BufWriter<std::fs::File>,
        csv: bool,
        n: usize,
    },
}

impl TraceSink {
    /// The disabled sink (same as `Default`).
    pub fn off() -> TraceSink {
        TraceSink::default()
    }

    /// Collect events in memory (replay/tests).
    pub fn mem() -> TraceSink {
        #[cfg(feature = "sim-trace")]
        {
            TraceSink { inner: Inner::Mem(Vec::new()) }
        }
        #[cfg(not(feature = "sim-trace"))]
        TraceSink::default()
    }

    /// Stream events to a file: `.csv` extension writes the flat CSV
    /// view, anything else writes replayable JSONL.
    pub fn file(path: impl AsRef<Path>) -> Result<TraceSink> {
        let path = path.as_ref();
        #[cfg(feature = "sim-trace")]
        {
            if let Some(dir) = path.parent() {
                if !dir.as_os_str().is_empty() {
                    std::fs::create_dir_all(dir)
                        .with_context(|| format!("creating {}", dir.display()))?;
                }
            }
            let f = std::fs::File::create(path)
                .with_context(|| format!("creating trace {}", path.display()))?;
            let csv = path.extension().and_then(|e| e.to_str()) == Some("csv");
            let mut w = std::io::BufWriter::new(f);
            if csv {
                writeln!(w, "{}", Event::CSV_HEADER)?;
            }
            Ok(TraceSink { inner: Inner::File { w, csv, n: 0 } })
        }
        #[cfg(not(feature = "sim-trace"))]
        {
            bail!("trace output requires the `sim-trace` feature (path: {})", path.display())
        }
    }

    /// Whether events are being collected.
    #[inline(always)]
    pub fn enabled(&self) -> bool {
        #[cfg(feature = "sim-trace")]
        {
            !matches!(self.inner, Inner::Off)
        }
        #[cfg(not(feature = "sim-trace"))]
        false
    }

    /// Record one event.  `f` is only invoked when the sink is enabled,
    /// so disabled-path cost is the `Off` check.
    #[inline(always)]
    pub fn record(&mut self, f: impl FnOnce() -> Event) {
        #[cfg(feature = "sim-trace")]
        match &mut self.inner {
            Inner::Off => {}
            Inner::Mem(v) => v.push(f()),
            Inner::File { w, csv, n } => {
                let e = f();
                let res = if *csv {
                    writeln!(w, "{}", e.csv_cells().join(","))
                } else {
                    writeln!(w, "{}", e.to_json().dump())
                };
                if res.is_ok() {
                    *n += 1;
                }
            }
        }
        #[cfg(not(feature = "sim-trace"))]
        let _ = f;
    }

    /// Events collected so far (empty unless a `Mem` sink).
    pub fn events(&self) -> &[Event] {
        #[cfg(feature = "sim-trace")]
        if let Inner::Mem(v) = &self.inner {
            return v;
        }
        &[]
    }

    /// Consume the sink, returning collected events (`Mem` only).
    pub fn into_events(self) -> Vec<Event> {
        #[cfg(feature = "sim-trace")]
        if let Inner::Mem(v) = self.inner {
            return v;
        }
        Vec::new()
    }

    /// Number of events recorded.
    pub fn len(&self) -> usize {
        #[cfg(feature = "sim-trace")]
        match &self.inner {
            Inner::Off => 0,
            Inner::Mem(v) => v.len(),
            Inner::File { n, .. } => *n,
        }
        #[cfg(not(feature = "sim-trace"))]
        0
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Flush buffered output (file sinks); returns the event count.
    pub fn finish(&mut self) -> Result<usize> {
        #[cfg(feature = "sim-trace")]
        if let Inner::File { w, .. } = &mut self.inner {
            w.flush().context("flushing trace")?;
        }
        Ok(self.len())
    }
}

// ============================================================= phase profiler

/// Interval phases, in `step_interval` order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Event loop to the interval boundary: completions, faults,
    /// background load, hold release, feature snapshot.
    Advance,
    /// Job arrivals (workload generation + ground-truth sampling).
    Arrivals,
    /// Scheduler placement of pending tasks.
    Placement,
    /// `Manager::on_interval` — the technique's prediction/decision pass.
    Predict,
    /// Applying mitigation actions (speculate/rerun/hold).
    Mitigate,
    /// QoS metric snapshot.
    Metrics,
}

impl Phase {
    pub const ALL: [Phase; 6] = [
        Phase::Advance,
        Phase::Arrivals,
        Phase::Placement,
        Phase::Predict,
        Phase::Mitigate,
        Phase::Metrics,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Phase::Advance => "advance",
            Phase::Arrivals => "arrivals",
            Phase::Placement => "placement",
            Phase::Predict => "predict",
            Phase::Mitigate => "mitigate",
            Phase::Metrics => "metrics",
        }
    }
}

/// Sub-spans of one manager `on_interval` call, attributed inside the
/// Predict phase: feature extraction (window/M_T assembly), model
/// dispatch (the PJRT rollout call), and decision logic (threshold /
/// endgame scan over predictions).  Self-timed by instrumented managers
/// and drained by the engine via `Manager::take_predict_spans`.
#[derive(Clone, Copy, Debug, Default)]
pub struct PredictSpans {
    pub features: Duration,
    pub dispatch: Duration,
    pub decide: Duration,
}

impl PredictSpans {
    /// Span names in storage order (mirrors `Phase::name`).
    pub const NAMES: [&'static str; 3] = ["features", "dispatch", "decide"];

    fn nanos(&self) -> [u64; 3] {
        [
            self.features.as_nanos() as u64,
            self.dispatch.as_nanos() as u64,
            self.decide.as_nanos() as u64,
        ]
    }
}

/// Per-run wall-time attribution, accumulated in integer nanoseconds so
/// phase sums are exact (Duration arithmetic, no float drift): the
/// engine times predict and mitigate with contiguous `Instant`s, so
/// `predict + mitigate` spans exactly the old lump-sum Fig. 10
/// measurement around the manager block.
///
/// `predict_nanos` holds the manager-reported sub-span breakdown of the
/// Predict phase (`PredictSpans` order).  The sub-spans are measured
/// *inside* `on_interval`, so they sum to slightly less than the phase
/// itself (manager bookkeeping between spans is uninstrumented).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct PhaseProfile {
    nanos: [u64; 6],
    calls: [u64; 6],
    predict_nanos: [u64; 3],
    predict_calls: u64,
}

impl PhaseProfile {
    /// Accumulate one timed span.
    pub fn add(&mut self, p: Phase, d: Duration) {
        self.nanos[p as usize] += d.as_nanos() as u64;
        self.calls[p as usize] += 1;
    }

    /// Accumulate one manager-reported Predict sub-span breakdown.
    pub fn add_predict_spans(&mut self, s: &PredictSpans) {
        for (acc, n) in self.predict_nanos.iter_mut().zip(s.nanos()) {
            *acc += n;
        }
        self.predict_calls += 1;
    }

    /// Accumulated seconds of one Predict sub-span (`PredictSpans::NAMES`
    /// index), with the number of drained `on_interval` breakdowns.
    pub fn predict_span(&self, i: usize) -> (f64, u64) {
        (self.predict_nanos[i] as f64 * 1e-9, self.predict_calls)
    }

    /// Exact accumulated nanoseconds for a phase.
    pub fn nanos(&self, p: Phase) -> u64 {
        self.nanos[p as usize]
    }

    /// Number of timed spans for a phase.
    pub fn calls(&self, p: Phase) -> u64 {
        self.calls[p as usize]
    }

    /// Accumulated seconds for a phase.
    pub fn seconds(&self, p: Phase) -> f64 {
        self.nanos[p as usize] as f64 * 1e-9
    }

    /// Total profiled seconds across all phases.
    pub fn total_seconds(&self) -> f64 {
        self.nanos.iter().sum::<u64>() as f64 * 1e-9
    }

    /// Fig. 10's manager overhead: the predict + mitigate counters (the
    /// single definition — `RunMetrics::manager_overhead_s` delegates
    /// here).  Summed in nanoseconds, so it equals the old contiguous
    /// lump measurement around the manager block exactly.
    pub fn manager_overhead_s(&self) -> f64 {
        (self.nanos[Phase::Predict as usize] + self.nanos[Phase::Mitigate as usize]) as f64 * 1e-9
    }

    /// NaN-free JSON summary: per-phase seconds, call counts and mean
    /// span (0 when a phase never ran — no 0/0).
    pub fn to_json(&self) -> Json {
        let mut phases = Vec::new();
        for p in Phase::ALL {
            let calls = self.calls(p);
            let secs = self.seconds(p);
            let mean = if calls > 0 { secs / calls as f64 } else { 0.0 };
            let mut fields = vec![
                ("seconds", Json::Num(secs)),
                ("calls", num(calls as usize)),
                ("mean_s", Json::Num(mean)),
            ];
            if p == Phase::Predict {
                // Manager-reported sub-spans (zeroed when the technique
                // does not self-instrument; never NaN).
                let spans = PredictSpans::NAMES
                    .iter()
                    .enumerate()
                    .map(|(i, name)| {
                        let (s, c) = self.predict_span(i);
                        let mean = if c > 0 { s / c as f64 } else { 0.0 };
                        (
                            *name,
                            Json::obj(vec![
                                ("seconds", Json::Num(s)),
                                ("calls", num(c as usize)),
                                ("mean_s", Json::Num(mean)),
                            ]),
                        )
                    })
                    .collect();
                fields.push(("spans", Json::obj(spans)));
            }
            phases.push((p.name(), Json::obj(fields)));
        }
        let mut all = vec![
            ("total_s", Json::Num(self.total_seconds())),
            ("manager_overhead_s", Json::Num(self.manager_overhead_s())),
        ];
        all.extend(phases);
        Json::obj(all)
    }

    /// One CSV row of per-phase seconds (see `csv_header`).
    pub fn csv_row(&self, label: &str) -> String {
        let mut cells = vec![label.to_string()];
        for p in Phase::ALL {
            cells.push(format!("{}", self.seconds(p)));
        }
        cells.push(format!("{}", self.total_seconds()));
        cells.join(",")
    }

    pub fn csv_header() -> String {
        let mut cells = vec!["label".to_string()];
        for p in Phase::ALL {
            cells.push(format!("{}_s", p.name()));
        }
        cells.push("total_s".to_string());
        cells.join(",")
    }
}

// ===================================================================== replay

/// Re-derive `RunMetrics` from an event stream alone.
///
/// The invariant (enforced by `rust/tests/trace_replay.rs` for every
/// scheduler × technique cell, in both indexed and `reference_scans`
/// modes): for a live run `m` traced into `events`,
/// `replay(&events)` equals `m` on every deterministic field — the same
/// f64 bits, because each reduction repeats the live arithmetic on the
/// same operands in the same order (e.g. exec time = `TaskResult.t −
/// TaskAdmit.submit_t`, restart time = the ordered sum of `TaskReset`
/// penalties).  Wall-clock (`profile` / manager overhead) is excluded —
/// it is measurement, not simulation state.
pub fn replay(events: &[Event]) -> RunMetrics {
    let mut m = RunMetrics::default();
    let mut submit_t: HashMap<TaskId, f64> = HashMap::new();
    let mut restart: HashMap<TaskId, f64> = HashMap::new();
    let mut job_weight: HashMap<JobId, f64> = HashMap::new();
    let mut job_deadline: HashMap<JobId, f64> = HashMap::new();
    for ev in events {
        match ev {
            Event::TaskAdmit { task, submit_t: s, .. } => {
                submit_t.insert(*task, *s);
            }
            Event::TaskReset { task, penalty_s, .. } => {
                *restart.entry(*task).or_insert(0.0) += penalty_s;
            }
            Event::TaskResult { t, task, mitigated, straggler, .. } => {
                let s = submit_t.get(task).copied().unwrap_or(0.0);
                m.exec_times.push(t - s);
                m.restart_times.push(restart.get(task).copied().unwrap_or(0.0));
                m.completion_times.push(*t);
                m.tasks_done += 1;
                m.confusion.record(*mitigated, *straggler);
            }
            Event::JobAdmit { job, sla_weight, .. } => {
                job_weight.insert(*job, *sla_weight);
            }
            Event::JobSla { job, deadline, .. } => {
                job_deadline.insert(*job, *deadline);
            }
            Event::JobScore { t, job, predicted_es, actual_stragglers } => {
                let w = job_weight.get(job).copied().unwrap_or(0.0);
                m.sla_total_weight += w;
                if *t > job_deadline.get(job).copied().unwrap_or(0.0) {
                    m.sla_violated_weight += w;
                }
                m.straggler_pred.push((*predicted_es, *actual_stragglers as f64));
                m.jobs_done += 1;
            }
            Event::Mitigate { t, kind, applied, started, .. } => {
                if *applied {
                    match kind {
                        MitigationKind::Speculate => m.speculations += 1,
                        MitigationKind::Rerun => m.reruns += 1,
                        MitigationKind::Hold => {}
                    }
                    if !matches!(kind, MitigationKind::Hold) {
                        if let Some(s) = started {
                            m.mitigation_delays.push(t - s);
                        }
                    }
                }
            }
            Event::Interval { snapshot, .. } => m.intervals.push(snapshot.clone()),
            _ => {}
        }
    }
    m
}

// ==================================================================== recount

/// Live-set recount from the event stream (the trace-consistency arm of
/// the world property test): replays lifecycle transitions into
/// pending/running/held task sets and the active-job set, each in
/// ascending id order — directly comparable with the `World` accessors
/// and `assert_consistent`'s from-scratch scan.
#[derive(Debug, Default, PartialEq, Eq)]
pub struct Recount {
    pub pending: Vec<TaskId>,
    pub running: Vec<TaskId>,
    pub held: Vec<TaskId>,
    pub active_jobs: Vec<JobId>,
}

pub fn recount(events: &[Event]) -> Recount {
    let mut pending: BTreeSet<TaskId> = BTreeSet::new();
    let mut running: BTreeSet<TaskId> = BTreeSet::new();
    let mut held: BTreeSet<TaskId> = BTreeSet::new();
    let mut jobs: BTreeSet<JobId> = BTreeSet::new();
    let mut clear = |id: &TaskId,
                     p: &mut BTreeSet<TaskId>,
                     r: &mut BTreeSet<TaskId>,
                     h: &mut BTreeSet<TaskId>| {
        p.remove(id);
        r.remove(id);
        h.remove(id);
    };
    for ev in events {
        match ev {
            Event::TaskAdmit { task, state, .. } => match state {
                LifeState::Pending => {
                    pending.insert(*task);
                }
                LifeState::Running => {
                    running.insert(*task);
                }
                LifeState::Held => {
                    held.insert(*task);
                }
                LifeState::Done => {}
            },
            Event::TaskStart { task, .. } => {
                clear(task, &mut pending, &mut running, &mut held);
                running.insert(*task);
            }
            Event::TaskComplete { task, .. }
            | Event::TaskSuperseded { task, .. }
            | Event::TaskKill { task, .. } => {
                clear(task, &mut pending, &mut running, &mut held);
            }
            Event::TaskReset { task, .. } | Event::TaskRelease { task, .. } => {
                clear(task, &mut pending, &mut running, &mut held);
                pending.insert(*task);
            }
            Event::TaskHold { task, .. } => {
                clear(task, &mut pending, &mut running, &mut held);
                held.insert(*task);
            }
            Event::JobAdmit { job, .. } => {
                jobs.insert(*job);
            }
            Event::JobDone { job, .. } => {
                jobs.remove(job);
            }
            _ => {}
        }
    }
    Recount {
        pending: pending.into_iter().collect(),
        running: running.into_iter().collect(),
        held: held.into_iter().collect(),
        active_jobs: jobs.into_iter().collect(),
    }
}

// ====================================================================== tests

#[cfg(test)]
mod tests {
    use super::*;

    /// One of every variant with awkward payloads (irrational floats,
    /// None/Some options, empty vectors).
    fn one_of_each() -> Vec<Event> {
        vec![
            Event::Meta {
                seed: 42,
                n_intervals: 288,
                interval_s: 300.0,
                technique: "START".into(),
                scheduler: "A3c".into(),
            },
            Event::TaskAdmit {
                t: 0.1 + 0.2,
                task: TaskId::new(7),
                job: JobId::new(3),
                submit_t: std::f64::consts::PI,
                speculative_of: None,
                state: LifeState::Pending,
            },
            Event::TaskAdmit {
                t: 1.0,
                task: TaskId::new(8),
                job: JobId::new(3),
                submit_t: 1.0,
                speculative_of: Some(TaskId::new(7)),
                state: LifeState::Running,
            },
            Event::TaskStart { t: 2.5, task: TaskId::new(7), vm: VmId::new(11), slowdown: 1.0 / 3.0 },
            Event::TaskComplete { t: 3.0, task: TaskId::new(7) },
            Event::TaskSuperseded { t: 3.0, task: TaskId::new(9) },
            Event::TaskKill { t: 3.5, task: TaskId::new(8) },
            Event::TaskReset { t: 4.0, task: TaskId::new(10), penalty_s: 30.0 },
            Event::TaskHold { t: 4.5, task: TaskId::new(11), until: 600.125 },
            Event::TaskRelease { t: 600.25, task: TaskId::new(11) },
            Event::JobAdmit {
                t: 0.0,
                job: JobId::new(3),
                tasks: vec![TaskId::new(7), TaskId::new(9), TaskId::new(10)],
                deadline_driven: true,
                sla_weight: 2.5,
            },
            Event::JobAdmit {
                t: 0.0,
                job: JobId::new(4),
                tasks: vec![],
                deadline_driven: false,
                sla_weight: 1.0,
            },
            Event::JobSla { t: 0.0, job: JobId::new(3), deadline: 1234.567_890_123 },
            Event::JobDone { t: 900.0, job: JobId::new(3) },
            Event::TaskResult { t: 900.0, task: TaskId::new(7), job: JobId::new(3), mitigated: true, straggler: false },
            Event::JobScore { t: 900.0, job: JobId::new(3), predicted_es: 1.75, actual_stragglers: 2 },
            Event::Mitigate {
                t: 300.0,
                task: TaskId::new(7),
                kind: MitigationKind::Speculate,
                applied: true,
                started: Some(12.5),
            },
            Event::Mitigate {
                t: 300.0,
                task: TaskId::new(9),
                kind: MitigationKind::Hold,
                applied: false,
                started: None,
            },
            Event::Mitigate {
                t: 300.0,
                task: TaskId::new(10),
                kind: MitigationKind::Rerun,
                applied: true,
                started: None,
            },
            Event::Veto { t: 300.0, task: TaskId::new(12), vm: VmId::new(4) },
            Event::Fault { t: 301.0, fault: FaultEvent::Host { host: HostId::new(2), until: 901.0 } },
            Event::Fault { t: 302.0, fault: FaultEvent::Cloudlet { vm: VmId::new(5), task: Some(TaskId::new(7)) } },
            Event::Fault { t: 302.0, fault: FaultEvent::Cloudlet { vm: VmId::new(6), task: None } },
            Event::Fault { t: 303.0, fault: FaultEvent::VmCreation { vm: VmId::new(5), ready_at: 603.0 } },
            Event::Interval {
                index: 0,
                snapshot: IntervalMetrics {
                    t: 300.0,
                    energy_kwh: 0.123_456_789_012_345,
                    cpu_util: 0.5,
                    ram_util: 0.25,
                    disk_util: 0.125,
                    net_util: 1.0 / 7.0,
                    contention: 0.0,
                    active_tasks: 17,
                    hosts_down: 1,
                },
            },
        ]
    }

    #[test]
    fn jsonl_round_trip_every_variant() {
        let events = one_of_each();
        let mut buf = Vec::new();
        write_jsonl(&events, &mut buf).unwrap();
        let text = String::from_utf8(buf).unwrap();
        let back = read_jsonl(&text).unwrap();
        assert_eq!(events.len(), back.len());
        for (a, b) in events.iter().zip(&back) {
            assert_eq!(a, b, "round-trip drift for {}", a.tag());
        }
    }

    #[test]
    fn jsonl_round_trip_is_bitwise_for_floats() {
        // Shortest-representation float printing must reproduce exact
        // bits — the replay contract relies on it.
        for v in [0.1 + 0.2, 1.0 / 3.0, f64::MIN_POSITIVE, 1e300, -123.456e-7] {
            let e = Event::TaskStart { t: v, task: TaskId::new(0), vm: VmId::new(0), slowdown: v };
            let back = read_jsonl(&format!("{}\n", e.to_json().dump())).unwrap();
            match &back[0] {
                Event::TaskStart { t, slowdown, .. } => {
                    assert_eq!(t.to_bits(), v.to_bits());
                    assert_eq!(slowdown.to_bits(), v.to_bits());
                }
                other => panic!("wrong variant {other:?}"),
            }
        }
    }

    #[test]
    fn typed_ids_round_trip_bitwise_through_jsonl() {
        // Entity-id newtypes serialize as bare arena indices (the JSONL
        // schema is unchanged from the `usize`-alias era) and must come
        // back exact for every representable index.  Ids ride through
        // JSON as f64, so the ceiling is 2^53 - 1 — far beyond any arena.
        const MAX_EXACT: usize = (1usize << 53) - 1;
        for raw in [0usize, 1, 4095, 1 << 32, MAX_EXACT] {
            let events = vec![
                Event::TaskAdmit {
                    t: 1.0,
                    task: TaskId::new(raw),
                    job: JobId::new(raw),
                    submit_t: 0.0,
                    speculative_of: Some(TaskId::new(raw)),
                    state: LifeState::Pending,
                },
                Event::TaskStart { t: 2.0, task: TaskId::new(raw), vm: VmId::new(raw), slowdown: 1.0 },
                Event::JobAdmit {
                    t: 0.0,
                    job: JobId::new(raw),
                    tasks: vec![TaskId::new(raw)],
                    deadline_driven: false,
                    sla_weight: 1.0,
                },
                Event::Veto { t: 3.0, task: TaskId::new(raw), vm: VmId::new(raw) },
                Event::Fault { t: 4.0, fault: FaultEvent::Host { host: HostId::new(raw), until: 9.0 } },
            ];
            let mut buf = Vec::new();
            write_jsonl(&events, &mut buf).unwrap();
            let back = read_jsonl(std::str::from_utf8(&buf).unwrap()).unwrap();
            assert_eq!(events, back, "id {raw} drifted through JSONL");
            match &back[0] {
                Event::TaskAdmit { task, job, speculative_of, .. } => {
                    assert_eq!(task.raw(), raw);
                    assert_eq!(job.raw(), raw);
                    assert_eq!(speculative_of.map(|t| t.raw()), Some(raw));
                }
                other => panic!("wrong variant {other:?}"),
            }
            match &back[4] {
                Event::Fault { fault: FaultEvent::Host { host, .. }, .. } => {
                    assert_eq!(host.raw(), raw);
                }
                other => panic!("wrong variant {other:?}"),
            }
        }
    }

    #[test]
    fn csv_rows_have_fixed_arity() {
        let header_cols = Event::CSV_HEADER.split(',').count();
        for e in one_of_each() {
            let cells = e.csv_cells();
            assert_eq!(cells.len(), header_cols, "{}", e.tag());
            for c in &cells {
                assert!(!c.contains(','), "{}: cell {c:?} would break CSV", e.tag());
            }
        }
    }

    #[test]
    fn read_jsonl_rejects_garbage() {
        assert!(read_jsonl("{\"ev\":\"task_start\"}").is_err()); // missing fields
        assert!(read_jsonl("{\"ev\":\"warp\"}").is_err()); // unknown tag
        assert!(read_jsonl("not json").is_err());
        assert!(read_jsonl("").unwrap().is_empty());
        assert!(read_jsonl("\n  \n").unwrap().is_empty());
    }

    #[test]
    fn replay_of_empty_stream_is_default_metrics() {
        let m = replay(&[]);
        assert_eq!(m.tasks_done, 0);
        assert_eq!(m.jobs_done, 0);
        assert!(m.intervals.is_empty());
        assert!(m.exec_times.is_empty());
        assert_eq!(m.sla_total_weight, 0.0);
    }

    #[test]
    fn replay_reduces_lifecycle_arithmetic() {
        let events = vec![
            Event::JobAdmit {
                t: 0.0,
                job: JobId::new(0),
                tasks: vec![TaskId::new(0)],
                deadline_driven: true,
                sla_weight: 2.0,
            },
            Event::JobSla { t: 0.0, job: JobId::new(0), deadline: 50.0 },
            Event::TaskAdmit {
                t: 0.0,
                task: TaskId::new(0),
                job: JobId::new(0),
                submit_t: 10.0,
                speculative_of: None,
                state: LifeState::Pending,
            },
            Event::TaskReset { t: 20.0, task: TaskId::new(0), penalty_s: 30.0 },
            Event::TaskReset { t: 40.0, task: TaskId::new(0), penalty_s: 30.0 },
            Event::Mitigate {
                t: 45.0,
                task: TaskId::new(0),
                kind: MitigationKind::Rerun,
                applied: true,
                started: Some(15.0),
            },
            Event::TaskResult { t: 100.0, task: TaskId::new(0), job: JobId::new(0), mitigated: true, straggler: true },
            Event::JobScore { t: 100.0, job: JobId::new(0), predicted_es: 1.0, actual_stragglers: 1 },
        ];
        let m = replay(&events);
        assert_eq!(m.exec_times, vec![90.0]);
        assert_eq!(m.restart_times, vec![60.0]);
        assert_eq!(m.completion_times, vec![100.0]);
        assert_eq!(m.mitigation_delays, vec![30.0]);
        assert_eq!(m.reruns, 1);
        assert_eq!(m.speculations, 0);
        assert_eq!((m.sla_violated_weight, m.sla_total_weight), (2.0, 2.0));
        assert_eq!(m.straggler_pred, vec![(1.0, 1.0)]);
        assert_eq!(m.confusion.tp, 1);
        assert_eq!(m.jobs_done, 1);
        assert_eq!(m.tasks_done, 1);
    }

    #[test]
    fn recount_tracks_transitions() {
        let mk_admit = |task, state| Event::TaskAdmit {
            t: 0.0,
            task,
            job: JobId::new(0),
            submit_t: 0.0,
            speculative_of: None,
            state,
        };
        let events = vec![
            Event::JobAdmit {
                t: 0.0,
                job: JobId::new(0),
                tasks: vec![TaskId::new(0), TaskId::new(1), TaskId::new(2)],
                deadline_driven: false,
                sla_weight: 1.0,
            },
            mk_admit(TaskId::new(0), LifeState::Pending),
            mk_admit(TaskId::new(1), LifeState::Pending),
            mk_admit(TaskId::new(2), LifeState::Pending),
            Event::TaskStart { t: 1.0, task: TaskId::new(0), vm: VmId::new(0), slowdown: 1.0 },
            Event::TaskHold { t: 1.0, task: TaskId::new(1), until: 10.0 },
            Event::TaskComplete { t: 5.0, task: TaskId::new(0) },
            Event::TaskRelease { t: 10.0, task: TaskId::new(1) },
        ];
        let rc = recount(&events);
        assert_eq!(rc.pending, vec![TaskId::new(1), TaskId::new(2)]);
        assert!(rc.running.is_empty());
        assert!(rc.held.is_empty());
        assert_eq!(rc.active_jobs, vec![JobId::new(0)]);
    }

    #[test]
    fn profiler_output_is_nan_free_even_when_empty() {
        // Zero-interval runs never tick any phase: the JSON summary must
        // still contain only finite numbers (no 0/0 means).
        let empty = PhaseProfile::default();
        fn assert_finite(v: &Json, path: &str) {
            match v {
                Json::Num(n) => assert!(n.is_finite(), "{path} = {n}"),
                Json::Obj(m) => {
                    for (k, x) in m {
                        assert_finite(x, &format!("{path}.{k}"));
                    }
                }
                Json::Arr(a) => {
                    for (i, x) in a.iter().enumerate() {
                        assert_finite(x, &format!("{path}[{i}]"));
                    }
                }
                _ => {}
            }
        }
        assert_finite(&empty.to_json(), "profile");
        assert_eq!(empty.manager_overhead_s(), 0.0);
        assert_eq!(empty.total_seconds(), 0.0);
        // And with data: means are per-call, still finite.
        let mut p = PhaseProfile::default();
        p.add(Phase::Predict, Duration::from_micros(250));
        p.add(Phase::Mitigate, Duration::from_micros(750));
        assert_finite(&p.to_json(), "profile");
        assert_eq!(p.manager_overhead_s(), 1e-3);
        assert_eq!(p.calls(Phase::Predict), 1);
        // CSV row arity matches the header.
        assert_eq!(
            p.csv_row("x").split(',').count(),
            PhaseProfile::csv_header().split(',').count()
        );
    }

    #[test]
    fn metrics_json_round_trip_is_exact() {
        // The coordinator journal's contract: metrics survive the JSONL
        // round trip bit-identically (deterministic fields) and the
        // profiler counters exactly.
        let mut m = RunMetrics {
            exec_times: vec![0.1 + 0.2, std::f64::consts::PI, 1.0 / 3.0],
            restart_times: vec![0.0, 30.0, 1e-12],
            completion_times: vec![300.0, 600.0, 12345.678_901_234_5],
            sla_violated_weight: 2.5,
            sla_total_weight: 7.0 / 3.0,
            straggler_pred: vec![(1.75, 2.0), (0.0, 0.0)],
            mitigation_delays: vec![12.5],
            speculations: 3,
            reruns: 1,
            jobs_done: 2,
            tasks_done: 3,
            ..RunMetrics::default()
        };
        m.confusion.record(true, true);
        m.confusion.record(false, true);
        m.intervals.push(IntervalMetrics {
            t: 300.0,
            energy_kwh: 0.123_456_789_012_345,
            cpu_util: 1.0 / 7.0,
            ram_util: 0.25,
            disk_util: 0.125,
            net_util: 0.5,
            contention: 0.0,
            active_tasks: 17,
            hosts_down: 1,
        });
        m.profile.add(Phase::Predict, Duration::from_nanos(123_456_789));
        m.profile.add(Phase::Mitigate, Duration::from_nanos(42));
        m.profile.add_predict_spans(&PredictSpans {
            features: Duration::from_nanos(11),
            dispatch: Duration::from_nanos(22),
            decide: Duration::from_nanos(33),
        });

        let text = metrics_to_json(&m).dump();
        let back = metrics_from_json(&crate::util::json::parse(&text).unwrap()).unwrap();
        assert!(m.diff_deterministic(&back).is_none(), "{:?}", m.diff_deterministic(&back));
        assert_eq!(m.profile, back.profile, "profiler counters must round-trip exactly");

        // A default (empty) RunMetrics round-trips too.
        let empty = RunMetrics::default();
        let back = metrics_from_json(&crate::util::json::parse(&metrics_to_json(&empty).dump()).unwrap())
            .unwrap();
        assert!(empty.diff_deterministic(&back).is_none());
        assert_eq!(empty.profile, back.profile);
    }

    #[test]
    fn metrics_from_json_rejects_malformed() {
        let good = metrics_to_json(&RunMetrics::default());
        assert!(metrics_from_json(&good).is_ok());
        assert!(metrics_from_json(&Json::obj(vec![])).is_err());
        // Wrong arity in the profile counters is caught, not truncated.
        let mut bad = good.clone();
        if let Json::Obj(map) = &mut bad {
            map.insert(
                "profile".into(),
                Json::obj(vec![
                    ("nanos", Json::Arr(vec![Json::Num(1.0)])),
                    ("calls", Json::Arr(vec![])),
                    ("predict_nanos", Json::Arr(vec![])),
                    ("predict_calls", Json::Num(0.0)),
                ]),
            );
        }
        assert!(metrics_from_json(&bad).is_err());
    }

    #[cfg(feature = "sim-trace")]
    #[test]
    fn sink_modes() {
        let mut off = TraceSink::off();
        off.record(|| panic!("disabled sink must not build events"));
        assert!(!off.enabled());
        assert_eq!(off.len(), 0);

        let mut mem = TraceSink::mem();
        assert!(mem.enabled());
        mem.record(|| Event::TaskComplete { t: 1.0, task: TaskId::new(0) });
        assert_eq!(mem.len(), 1);
        assert_eq!(mem.events().len(), 1);
        assert_eq!(mem.into_events().len(), 1);

        let dir = std::env::temp_dir().join(format!("start_sim_trace_{}", std::process::id()));
        let path = dir.join("t.jsonl");
        let mut file = TraceSink::file(&path).unwrap();
        file.record(|| Event::TaskComplete { t: 1.0, task: TaskId::new(0) });
        assert_eq!(file.finish().unwrap(), 1);
        drop(file);
        let back = load_jsonl(&path).unwrap();
        assert_eq!(back, vec![Event::TaskComplete { t: 1.0, task: TaskId::new(0) }]);
        let csv_path = dir.join("t.csv");
        let mut csv = TraceSink::file(&csv_path).unwrap();
        csv.record(|| Event::TaskComplete { t: 1.0, task: TaskId::new(0) });
        csv.finish().unwrap();
        drop(csv);
        let text = std::fs::read_to_string(&csv_path).unwrap();
        assert!(text.starts_with(Event::CSV_HEADER));
        assert_eq!(text.lines().count(), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
