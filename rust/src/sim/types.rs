//! Core simulator entity types: hosts, VMs, tasks (cloudlets), jobs.
//!
//! Entity ids are `#[repr(transparent)]` newtypes defined in
//! `sim::world::ids` (re-exported here so `use sim::types::*` keeps
//! working); mixing a `TaskId` into a host arena is a compile error.

pub use crate::sim::world::ids::{EntityId, HostId, JobId, TaskId, VmId};

/// A physical machine (Table 3).
#[derive(Clone, Debug)]
pub struct Host {
    pub id: HostId,
    /// Index into `SimConfig::pm_types`.
    pub type_idx: usize,
    pub mips_total: f64,
    pub ram_gb: f64,
    pub disk_gb: f64,
    pub bw_kbps: f64,
    pub power_idle_w: f64,
    pub power_peak_w: f64,
    pub cost_per_interval: f64,
    pub vms: Vec<VmId>,
    /// None = serviceable; Some(t) = down until simulated time t.
    pub down_until: Option<f64>,
    /// Moving average of stragglers observed on this host (Alg. 1's
    /// target-selection signal).
    pub straggler_ema: f64,
    /// Background (PlanetLab-trace) load fraction for the current interval.
    pub background_load: f64,
}

impl Host {
    pub fn is_up(&self, now: f64) -> bool {
        match self.down_until {
            Some(t) => now >= t,
            None => true,
        }
    }

    /// MIPS actually available to VMs after background + reserved load.
    pub fn effective_mips(&self, reserved: f64) -> f64 {
        let free = (1.0 - self.background_load - reserved).max(0.05);
        self.mips_total * free
    }
}

/// A virtual machine pinned to a host.
#[derive(Clone, Debug)]
pub struct Vm {
    pub id: VmId,
    pub host: HostId,
    /// Nominal MIPS share of the host when uncontended.
    pub mips: f64,
    pub ram_gb: f64,
    /// Tasks currently resident (running) on this VM.
    pub tasks: Vec<TaskId>,
    /// VM-creation fault: unavailable until this time.
    pub ready_at: f64,
}

/// Cloudlet resource requirements (Table 4 ranges, normalized by the
/// workload generator).
#[derive(Clone, Copy, Debug, Default)]
pub struct TaskDemand {
    pub mips: f64,
    pub ram_gb: f64,
    pub disk_gb: f64,
    pub bw_kbps: f64,
}

/// Lifecycle of a task.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TaskState {
    /// Waiting for placement.
    Pending,
    /// Executing on a VM.
    Running,
    /// Finished successfully at `t`.
    Completed { t: f64 },
    /// Killed (lost speculation race, or re-run superseded it).
    Killed,
    /// Delayed by the manager (Wrangler-style) until `t`.
    Held { until: f64 },
}

/// A cloudlet: one task of a bag-of-tasks job.
#[derive(Clone, Debug)]
pub struct Task {
    pub id: TaskId,
    pub job: JobId,
    /// Total work in million instructions.
    pub length_mi: f64,
    pub demand: TaskDemand,
    pub state: TaskState,
    pub vm: Option<VmId>,
    /// Last VM the task ran on (survives unplacement; for feedback/features).
    pub last_vm: Option<VmId>,
    /// Remaining work (MI) — decremented by the engine.
    pub remaining_mi: f64,
    pub submit_t: f64,
    /// First time the task started running (for response-time metrics).
    pub first_start_t: Option<f64>,
    /// Cumulative restart delay R_i (Eq. 8).
    pub restart_time: f64,
    pub restarts: u32,
    /// Pareto duration multiplier sampled at (re)start; rate is divided by
    /// this, so heavy-tail samples produce stragglers.
    pub slowdown: f64,
    /// For a speculative copy: the original task it races.
    pub speculative_of: Option<TaskId>,
    /// Set once a mitigation action has been taken for this task.
    pub mitigated: bool,
}

impl Task {
    pub fn is_active(&self) -> bool {
        matches!(self.state, TaskState::Pending | TaskState::Running | TaskState::Held { .. })
    }

    pub fn is_running(&self) -> bool {
        self.state == TaskState::Running
    }

    /// Fraction of work completed.
    pub fn progress(&self) -> f64 {
        1.0 - (self.remaining_mi / self.length_mi).clamp(0.0, 1.0)
    }
}

/// Lifecycle of a job.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum JobState {
    Active,
    /// All tasks completed at `t`.
    Done { t: f64 },
}

/// A bag-of-tasks job (paper §3: 2 ≤ q ≤ q′ = 10 tasks).
#[derive(Clone, Debug)]
pub struct Job {
    pub id: JobId,
    pub tasks: Vec<TaskId>,
    pub submit_t: f64,
    pub deadline_driven: bool,
    /// SLA deadline (absolute time) and weight w_i (Eq. 13).
    pub sla_deadline: f64,
    pub sla_weight: f64,
    pub state: JobState,
    /// Ground-truth Pareto parameters sampled at submission (the paper's
    /// "underlying distribution" of this job's task times).
    pub true_alpha: f64,
    pub true_beta: f64,
}

impl Job {
    pub fn is_active(&self) -> bool {
        self.state == JobState::Active
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_host() -> Host {
        Host {
            id: HostId::new(0),
            type_idx: 0,
            mips_total: 4000.0,
            ram_gb: 6.0,
            disk_gb: 320.0,
            bw_kbps: 1.5,
            power_idle_w: 108.0,
            power_peak_w: 273.0,
            cost_per_interval: 3.0,
            vms: vec![],
            down_until: None,
            straggler_ema: 0.0,
            background_load: 0.0,
        }
    }

    #[test]
    fn host_up_down() {
        let mut h = mk_host();
        assert!(h.is_up(0.0));
        h.down_until = Some(100.0);
        assert!(!h.is_up(50.0));
        assert!(h.is_up(100.0));
    }

    #[test]
    fn effective_mips_floors_at_5_percent() {
        let mut h = mk_host();
        h.background_load = 0.5;
        assert!((h.effective_mips(0.2) - 4000.0 * 0.3).abs() < 1e-9);
        h.background_load = 0.99;
        assert!((h.effective_mips(0.8) - 4000.0 * 0.05).abs() < 1e-9);
    }

    #[test]
    fn task_progress() {
        let t = Task {
            id: TaskId::new(0),
            job: JobId::new(0),
            length_mi: 100.0,
            demand: TaskDemand::default(),
            state: TaskState::Running,
            vm: Some(VmId::new(0)),
            last_vm: Some(VmId::new(0)),
            remaining_mi: 25.0,
            submit_t: 0.0,
            first_start_t: Some(0.0),
            restart_time: 0.0,
            restarts: 0,
            slowdown: 1.0,
            speculative_of: None,
            mitigated: false,
        };
        assert!((t.progress() - 0.75).abs() < 1e-12);
        assert!(t.is_active() && t.is_running());
    }
}
