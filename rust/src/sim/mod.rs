//! CloudSim-style discrete-event cloud simulator (the paper's evaluation
//! substrate, §4.3, rebuilt in Rust).
//!
//! Entities mirror CloudSim's: physical **hosts** (Table 3 PM types) run
//! **VMs**; **cloudlets** (tasks) belonging to bag-of-tasks **jobs** are
//! placed on VMs by a scheduling policy.  Execution is exact
//! piecewise-linear: every event advances all running tasks by
//! `dt × rate`, where rates only change at events (placement, completion,
//! fault), so no progress is approximated.  A Weibull fault injector
//! (FIM-SIM analogue) produces host / cloudlet / VM-creation faults.
//!
//! Straggler dynamics come from the shared generative model
//! (`trace::generative`): at task start a duration multiplier is sampled
//! from Pareto(α*, β*) where (α*, β*) are functions of the current cluster
//! feature matrices — the same functions the Encoder-LSTM was trained to
//! recover from those features.

pub mod engine;
pub mod faults;
pub mod metrics;
pub mod trace;
pub mod types;
pub mod world;

pub use engine::{Manager, NullManager, Simulation};
pub use metrics::{IntervalMetrics, RunMetrics};
pub use trace::{Event, Phase, PhaseProfile, TraceSink};
pub use types::*;
pub use world::World;
