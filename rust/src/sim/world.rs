//! The mutable simulation world: entity storage, capacity/contention math,
//! task placement and exact piecewise-linear progress advancement.

use crate::config::SimConfig;
use crate::sim::types::*;

/// Entity storage + derived execution rates.
pub struct World {
    pub now: f64,
    pub hosts: Vec<Host>,
    pub vms: Vec<Vm>,
    pub tasks: Vec<Task>,
    pub jobs: Vec<Job>,
    /// Reserved-utilization knob (Fig. 6/8 sweep).
    pub reserved_util: f64,
    /// Per-task execution rate in MI/s (slowdown already applied);
    /// recomputed lazily when `rates_dirty`.
    rates: Vec<f64>,
    rates_dirty: bool,
    /// Latest raw M_H snapshot (set by the coordinator's feature extractor
    /// each interval; consumed by job-submission generative sampling).
    pub latest_m_h: Vec<f32>,
    /// Completed-task log for metrics: (task, completion_time).
    pub completed_log: Vec<TaskId>,
}

impl World {
    /// Build the PM fleet + VMs from config.
    pub fn new(cfg: &SimConfig) -> World {
        let mut hosts = Vec::new();
        let mut vms = Vec::new();
        for (type_idx, (&count, ty)) in cfg.pm_counts.iter().zip(&cfg.pm_types).enumerate() {
            for _ in 0..count {
                let hid = hosts.len();
                let mut host = Host {
                    id: hid,
                    type_idx,
                    mips_total: ty.mips_per_core * ty.cores as f64,
                    ram_gb: ty.ram_gb,
                    disk_gb: ty.disk_gb,
                    bw_kbps: ty.bw_kbps,
                    power_idle_w: ty.power_idle_w,
                    power_peak_w: ty.power_peak_w,
                    cost_per_interval: ty.cost_per_interval,
                    vms: Vec::new(),
                    down_until: None,
                    straggler_ema: 0.0,
                    background_load: 0.0,
                };
                for _ in 0..ty.vms_per_pm {
                    let vid = vms.len();
                    host.vms.push(vid);
                    vms.push(Vm {
                        id: vid,
                        host: hid,
                        mips: host.mips_total / ty.vms_per_pm as f64,
                        ram_gb: ty.ram_gb / ty.vms_per_pm as f64,
                        tasks: Vec::new(),
                        ready_at: 0.0,
                    });
                }
                hosts.push(host);
            }
        }
        World {
            now: 0.0,
            hosts,
            vms,
            tasks: Vec::new(),
            jobs: Vec::new(),
            reserved_util: cfg.reserved_util,
            rates: Vec::new(),
            rates_dirty: true,
            latest_m_h: Vec::new(),
            completed_log: Vec::new(),
        }
    }

    // ------------------------------------------------------------ queries

    /// Active (pending/running/held) tasks of a job.
    pub fn active_tasks(&self, job: JobId) -> Vec<TaskId> {
        self.jobs[job]
            .tasks
            .iter()
            .copied()
            .filter(|&t| self.tasks[t].is_active())
            .collect()
    }

    /// Completed tasks of a job (non-speculative originals count once).
    pub fn completed_tasks(&self, job: JobId) -> usize {
        self.jobs[job]
            .tasks
            .iter()
            .filter(|&&t| matches!(self.tasks[t].state, TaskState::Completed { .. }))
            .count()
    }

    /// Whether a VM can currently accept work.
    pub fn vm_available(&self, vm: VmId) -> bool {
        let v = &self.vms[vm];
        v.ready_at <= self.now && self.hosts[v.host].is_up(self.now)
    }

    /// Sum of task MIPS demand currently on a VM (capped per task by fair share).
    fn vm_demand(&self, vm: VmId) -> f64 {
        let v = &self.vms[vm];
        let n = v.tasks.len().max(1) as f64;
        let fair = v.mips / n;
        v.tasks
            .iter()
            .map(|&t| self.tasks[t].demand.mips.min(fair).max(1.0))
            .sum()
    }

    /// Host CPU utilization in [0, 1] including background + reserved load.
    pub fn host_cpu_util(&self, host: HostId) -> f64 {
        let h = &self.hosts[host];
        if !h.is_up(self.now) {
            return 0.0;
        }
        let demand: f64 = h.vms.iter().map(|&v| self.vm_demand(v)).sum();
        (demand / h.mips_total + h.background_load + self.reserved_util).min(1.0)
    }

    /// Host RAM utilization in [0, 1].
    pub fn host_ram_util(&self, host: HostId) -> f64 {
        let h = &self.hosts[host];
        let used: f64 = h
            .vms
            .iter()
            .flat_map(|&v| self.vms[v].tasks.iter())
            .map(|&t| self.tasks[t].demand.ram_gb)
            .sum();
        (used / h.ram_gb + 0.5 * h.background_load + 0.5 * self.reserved_util).min(1.0)
    }

    /// Host disk utilization in [0, 1].
    pub fn host_disk_util(&self, host: HostId) -> f64 {
        let h = &self.hosts[host];
        let used: f64 = h
            .vms
            .iter()
            .flat_map(|&v| self.vms[v].tasks.iter())
            .map(|&t| self.tasks[t].demand.disk_gb)
            .sum();
        (used / h.disk_gb + 0.3 * self.reserved_util).min(1.0)
    }

    /// Host network utilization in [0, 1].
    pub fn host_bw_util(&self, host: HostId) -> f64 {
        let h = &self.hosts[host];
        let used: f64 = h
            .vms
            .iter()
            .flat_map(|&v| self.vms[v].tasks.iter())
            .map(|&t| self.tasks[t].demand.bw_kbps)
            .sum();
        (used / h.bw_kbps.max(1e-9) + 0.3 * self.reserved_util).min(1.0)
    }

    /// Number of running tasks on a host.
    pub fn host_task_count(&self, host: HostId) -> usize {
        self.hosts[host].vms.iter().map(|&v| self.vms[v].tasks.len()).sum()
    }

    // --------------------------------------------------------- placement

    /// Start (or restart) a task on a VM.  `slowdown` is the Pareto
    /// duration multiplier sampled by the caller from the job's
    /// ground-truth distribution.
    pub fn start_task(&mut self, task: TaskId, vm: VmId, slowdown: f64) {
        debug_assert!(self.tasks[task].vm.is_none(), "task already placed");
        let t = &mut self.tasks[task];
        t.state = TaskState::Running;
        t.vm = Some(vm);
        t.last_vm = Some(vm);
        t.slowdown = slowdown.max(1e-3);
        if t.first_start_t.is_none() {
            t.first_start_t = Some(self.now);
        }
        self.vms[vm].tasks.push(task);
        self.rates_dirty = true;
    }

    /// Remove a task from its VM (completion, kill, restart).
    pub fn unplace_task(&mut self, task: TaskId) {
        if let Some(vm) = self.tasks[task].vm.take() {
            self.vms[vm].tasks.retain(|&t| t != task);
            self.rates_dirty = true;
        }
    }

    /// Mark a task completed now and detach it.
    pub fn complete_task(&mut self, task: TaskId) {
        self.unplace_task(task);
        self.tasks[task].state = TaskState::Completed { t: self.now };
        self.tasks[task].remaining_mi = 0.0;
        self.completed_log.push(task);
    }

    /// Kill a task (lost race / superseded) and detach it.
    pub fn kill_task(&mut self, task: TaskId) {
        self.unplace_task(task);
        self.tasks[task].state = TaskState::Killed;
    }

    /// Reset a task to pending with full work (restart after fault/rerun);
    /// accumulates restart bookkeeping.
    pub fn reset_task(&mut self, task: TaskId, restart_penalty_s: f64) {
        self.unplace_task(task);
        let t = &mut self.tasks[task];
        t.state = TaskState::Pending;
        t.remaining_mi = t.length_mi;
        t.restarts += 1;
        t.restart_time += restart_penalty_s;
    }

    // ----------------------------------------------------- rate computation

    /// Recompute per-task MI/s rates from the current topology.
    ///
    /// Model: each task's fair demand on its VM is
    /// `min(demand.mips, vm.mips / n_tasks)`; a host whose aggregate VM
    /// demand exceeds its effective capacity (after background + reserved
    /// load) scales every resident task proportionally — this is the
    /// resource-contention mechanism (Eq. 9's "overloaded" condition).
    fn recompute_rates(&mut self) {
        if self.rates.len() < self.tasks.len() {
            self.rates.resize(self.tasks.len(), 0.0);
        }
        for r in self.rates.iter_mut() {
            *r = 0.0;
        }
        for h in 0..self.hosts.len() {
            let host = &self.hosts[h];
            if !host.is_up(self.now) {
                continue;
            }
            let demand: f64 = host.vms.iter().map(|&v| self.vm_demand(v)).sum();
            if demand <= 0.0 {
                continue;
            }
            let capacity = host.effective_mips(self.reserved_util);
            let scale = (capacity / demand).min(1.0);
            for &v in &host.vms {
                let vm = &self.vms[v];
                let n = vm.tasks.len().max(1) as f64;
                let fair = vm.mips / n;
                for &t in &vm.tasks {
                    let nominal = self.tasks[t].demand.mips.min(fair).max(1.0);
                    self.rates[t] = nominal * scale / self.tasks[t].slowdown;
                }
            }
        }
        self.rates_dirty = false;
    }

    /// Force rate recomputation on next use (topology/load changed).
    pub fn mark_rates_dirty(&mut self) {
        self.rates_dirty = true;
    }

    /// Current rate of a task (MI/s).
    pub fn task_rate(&mut self, task: TaskId) -> f64 {
        if self.rates_dirty {
            self.recompute_rates();
        }
        self.rates.get(task).copied().unwrap_or(0.0)
    }

    /// Earliest projected completion time among running tasks.
    pub fn next_finish_time(&mut self) -> Option<f64> {
        if self.rates_dirty {
            self.recompute_rates();
        }
        let now = self.now;
        let mut best: Option<f64> = None;
        for t in 0..self.tasks.len() {
            if self.tasks[t].is_running() {
                let rate = self.rates[t];
                if rate > 0.0 {
                    let eta = now + self.tasks[t].remaining_mi / rate;
                    best = Some(match best {
                        Some(b) => b.min(eta),
                        None => eta,
                    });
                }
            }
        }
        best
    }

    /// Advance simulated time to `to`, consuming work on all running
    /// tasks.  Returns tasks whose remaining work reached zero.
    pub fn advance(&mut self, to: f64) -> Vec<TaskId> {
        debug_assert!(to >= self.now - 1e-9, "time must be monotone");
        if self.rates_dirty {
            self.recompute_rates();
        }
        let dt = (to - self.now).max(0.0);
        self.now = to;
        if dt == 0.0 {
            return Vec::new();
        }
        let mut done = Vec::new();
        for t in 0..self.tasks.len() {
            if self.tasks[t].is_running() {
                let rate = self.rates[t];
                if rate > 0.0 {
                    self.tasks[t].remaining_mi -= rate * dt;
                    if self.tasks[t].remaining_mi <= 1e-6 {
                        done.push(t);
                    }
                }
            }
        }
        done
    }

    /// Update the per-host straggler moving average (Alg. 1's node-choice
    /// signal): called when a task is classified at completion.
    pub fn note_straggler(&mut self, host: HostId, was_straggler: bool) {
        let h = &mut self.hosts[host];
        let x = if was_straggler { 1.0 } else { 0.0 };
        h.straggler_ema = 0.8 * h.straggler_ema + 0.2 * x;
    }

    /// Pick the up-VM on the host with the lowest straggler moving average
    /// (the paper's mitigation target choice), breaking ties toward
    /// unloaded hosts so mitigation does not itself create contention.
    pub fn best_mitigation_vm(&self, exclude_host: Option<HostId>) -> Option<VmId> {
        let mut best: Option<((i64, i64, usize), VmId)> = None;
        for v in 0..self.vms.len() {
            if !self.vm_available(v) {
                continue;
            }
            let host = self.vms[v].host;
            if Some(host) == exclude_host {
                continue;
            }
            // Quantized straggler EMA first (the paper's signal), then
            // host CPU utilization, then VM queue depth.
            let key = (
                (self.hosts[host].straggler_ema * 10.0) as i64,
                (self.host_cpu_util(host) * 20.0) as i64,
                self.vms[v].tasks.len(),
            );
            if best.map(|(b, _)| key < b).unwrap_or(true) {
                best = Some((key, v));
            }
        }
        best.map(|(_, v)| v)
    }

    /// Fleet-wide maxima used for feature normalization.
    pub fn fleet_max(&self) -> (f64, f64, f64, f64) {
        let mut m = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
        for h in &self.hosts {
            m.0 = m.0.max(h.mips_total);
            m.1 = m.1.max(h.ram_gb);
            m.2 = m.2.max(h.disk_gb);
            m.3 = m.3.max(h.bw_kbps);
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::sim::types::{TaskDemand, TaskState};

    fn world() -> World {
        World::new(&SimConfig::test_defaults())
    }

    fn add_task(w: &mut World, job: JobId, length: f64, mips: f64) -> TaskId {
        let id = w.tasks.len();
        w.tasks.push(Task {
            id,
            job,
            length_mi: length,
            demand: TaskDemand { mips, ram_gb: 0.1, disk_gb: 1.0, bw_kbps: 0.1 },
            state: TaskState::Pending,
            vm: None,
            last_vm: None,
            remaining_mi: length,
            submit_t: 0.0,
            first_start_t: None,
            restart_time: 0.0,
            restarts: 0,
            slowdown: 1.0,
            speculative_of: None,
            mitigated: false,
        });
        id
    }

    #[test]
    fn fleet_construction_matches_config() {
        let cfg = SimConfig::test_defaults();
        let w = World::new(&cfg);
        assert_eq!(w.hosts.len(), cfg.total_pms());
        assert_eq!(w.vms.len(), cfg.total_vms());
        // every VM belongs to its host's list exactly once
        for v in &w.vms {
            assert!(w.hosts[v.host].vms.contains(&v.id));
        }
    }

    #[test]
    fn uncontended_task_runs_at_demand_rate() {
        let mut w = world();
        let t = add_task(&mut w, 0, 1000.0, 100.0);
        w.start_task(t, 0, 1.0);
        let rate = w.task_rate(t);
        assert!((rate - 100.0).abs() < 1e-9, "rate {rate}");
        let done = w.advance(10.0);
        assert_eq!(done, vec![t]);
    }

    #[test]
    fn slowdown_divides_rate() {
        let mut w = world();
        let t = add_task(&mut w, 0, 1000.0, 100.0);
        w.start_task(t, 0, 4.0);
        assert!((w.task_rate(t) - 25.0).abs() < 1e-9);
    }

    #[test]
    fn vm_fair_share_caps_rate() {
        let mut w = world();
        let vm_mips = w.vms[0].mips;
        let t1 = add_task(&mut w, 0, 1e6, 1e9);
        let t2 = add_task(&mut w, 0, 1e6, 1e9);
        w.start_task(t1, 0, 1.0);
        w.start_task(t2, 0, 1.0);
        let r1 = w.task_rate(t1);
        assert!((r1 - vm_mips / 2.0).abs() < 1e-6, "r1 {r1} vm {vm_mips}");
    }

    #[test]
    fn host_contention_scales_down() {
        let mut w = world();
        let host = 0;
        // Saturate every VM on host 0 with one huge-demand task.
        let vms: Vec<_> = w.hosts[host].vms.clone();
        let mut tasks = Vec::new();
        for &v in &vms {
            let t = add_task(&mut w, 0, 1e9, 1e9);
            w.start_task(t, v, 1.0);
            tasks.push(t);
        }
        // Also background load to force capacity below demand.
        w.hosts[host].background_load = 0.5;
        w.mark_rates_dirty();
        let total_rate: f64 = tasks.iter().map(|&t| w.task_rate(t)).sum();
        let cap = w.hosts[host].effective_mips(0.0);
        assert!(total_rate <= cap * 1.001, "total {total_rate} cap {cap}");
        assert!(w.host_cpu_util(host) >= 0.99);
    }

    #[test]
    fn advance_is_exact_piecewise() {
        let mut w = world();
        let t = add_task(&mut w, 0, 1000.0, 100.0);
        w.start_task(t, 0, 1.0);
        w.advance(3.0);
        assert!((w.tasks[t].remaining_mi - 700.0).abs() < 1e-9);
        assert!((w.tasks[t].progress() - 0.3).abs() < 1e-9);
        let eta = w.next_finish_time().unwrap();
        assert!((eta - 10.0).abs() < 1e-9);
    }

    #[test]
    fn down_host_contributes_no_rate() {
        let mut w = world();
        let t = add_task(&mut w, 0, 1000.0, 100.0);
        w.start_task(t, 0, 1.0);
        w.hosts[w.vms[0].host].down_until = Some(1e9);
        w.mark_rates_dirty();
        assert_eq!(w.task_rate(t), 0.0);
        assert!(w.next_finish_time().is_none());
    }

    #[test]
    fn reset_task_restores_work_and_counts_restart() {
        let mut w = world();
        let t = add_task(&mut w, 0, 1000.0, 100.0);
        w.start_task(t, 0, 1.0);
        w.advance(5.0);
        w.reset_task(t, 30.0);
        assert_eq!(w.tasks[t].state, TaskState::Pending);
        assert_eq!(w.tasks[t].remaining_mi, 1000.0);
        assert_eq!(w.tasks[t].restarts, 1);
        assert_eq!(w.tasks[t].restart_time, 30.0);
        assert!(w.vms[0].tasks.is_empty());
    }

    #[test]
    fn complete_and_kill_detach_from_vm() {
        let mut w = world();
        let t1 = add_task(&mut w, 0, 1000.0, 100.0);
        let t2 = add_task(&mut w, 0, 1000.0, 100.0);
        w.start_task(t1, 0, 1.0);
        w.start_task(t2, 0, 1.0);
        w.advance(1.0);
        w.complete_task(t1);
        w.kill_task(t2);
        assert!(matches!(w.tasks[t1].state, TaskState::Completed { .. }));
        assert_eq!(w.tasks[t2].state, TaskState::Killed);
        assert!(w.vms[0].tasks.is_empty());
        assert_eq!(w.completed_log, vec![t1]);
    }

    #[test]
    fn best_mitigation_vm_prefers_low_straggler_ema() {
        let mut w = world();
        for h in 0..w.hosts.len() {
            w.hosts[h].straggler_ema = 0.9;
        }
        let target_host = 3;
        w.hosts[target_host].straggler_ema = 0.0;
        let vm = w.best_mitigation_vm(None).unwrap();
        assert_eq!(w.vms[vm].host, target_host);
        // excluding that host picks another one
        let vm2 = w.best_mitigation_vm(Some(target_host)).unwrap();
        assert_ne!(w.vms[vm2].host, target_host);
    }

    #[test]
    fn straggler_ema_updates() {
        let mut w = world();
        w.note_straggler(0, true);
        assert!((w.hosts[0].straggler_ema - 0.2).abs() < 1e-12);
        w.note_straggler(0, false);
        assert!((w.hosts[0].straggler_ema - 0.16).abs() < 1e-12);
    }
}
