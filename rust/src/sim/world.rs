//! The mutable simulation world: entity storage, capacity/contention math,
//! task placement and exact piecewise-linear progress advancement.
//!
//! Entity storage is an **index-maintained registry** (DESIGN.md §3):
//! alongside the grow-only `tasks`/`jobs` arenas the world keeps
//! incrementally-updated membership sets — `pending`, `running`, `held`
//! tasks, `active_jobs`, per-job active-task counters, the live
//! speculative-clone map, and a lazy min-heap of projected finish times
//! that is invalidated only when execution rates change.  Every hot-path
//! query (`advance`, `next_finish_time`, placement, metrics, drain check)
//! is O(active) instead of O(total tasks ever created).
//!
//! Resource-load queries are **incrementally accounted** (DESIGN.md §9):
//! every VM carries cached demand subtotals (`ResLoad`) recomputed with
//! the reference arithmetic whenever its resident task set changes, and
//! every host carries the fold of its VMs' subtotals in `host.vms` order,
//! so `host_cpu_util` / `host_ram_util` / `host_disk_util` /
//! `host_bw_util` / `host_task_count` are O(1) reads instead of rescans
//! of every task on the host.  An **availability index** (member set +
//! wake-time heap + sorted cache, advanced as `now` moves) makes
//! `available_vms` enumerate only placeable VMs instead of filtering
//! `0..vms.len()`.
//!
//! The arenas are private: consumers go through the typed accessors
//! (`pending()`, `running()`, `active_jobs()`, `task()`, `job()`, …) and
//! all state transitions go through world methods so the indexes can never
//! drift from task state.  Host up/down and VM readiness changes likewise
//! go through `set_host_down` / `set_vm_ready_at`.
//! `SimConfig::reference_scans` flips every query back to the pre-index
//! O(total)/O(fleet) full scans — the golden-parity test and the `scale`
//! and `placement` benchmarks run both modes and compare.

use crate::config::SimConfig;
use crate::sim::trace::{Event, LifeState, TraceSink};
use crate::sim::types::*;
use std::borrow::Cow;
use std::cmp::{Ordering, Reverse};
use std::collections::{BinaryHeap, HashMap};

/// Dense membership set over entity ids: O(1) insert/remove/contains via a
/// swap-remove vec plus a position map, O(members) iteration.
#[derive(Default)]
struct IdSet {
    dense: Vec<usize>,
    pos: Vec<u32>,
}

const NO_POS: u32 = u32::MAX;

impl IdSet {
    fn insert(&mut self, id: usize) -> bool {
        if id >= self.pos.len() {
            self.pos.resize(id + 1, NO_POS);
        }
        if self.pos[id] != NO_POS {
            return false;
        }
        self.pos[id] = self.dense.len() as u32;
        self.dense.push(id);
        true
    }

    fn remove(&mut self, id: usize) -> bool {
        if id >= self.pos.len() || self.pos[id] == NO_POS {
            return false;
        }
        let i = self.pos[id] as usize;
        let last = *self.dense.last().unwrap();
        self.dense[i] = last;
        self.pos[last] = i as u32;
        self.dense.pop();
        self.pos[id] = NO_POS;
        true
    }

    fn contains(&self, id: usize) -> bool {
        id < self.pos.len() && self.pos[id] != NO_POS
    }

    fn len(&self) -> usize {
        self.dense.len()
    }

    fn is_empty(&self) -> bool {
        self.dense.is_empty()
    }

    fn clear(&mut self) {
        for &id in &self.dense {
            self.pos[id] = NO_POS;
        }
        self.dense.clear();
    }

    /// Members in ascending id order (the order the pre-index full scans
    /// produced — required for bit-identical replay).
    fn sorted(&self) -> Vec<usize> {
        let mut v = self.dense.clone();
        v.sort_unstable();
        v
    }
}

/// Total-ordered f64 wrapper for heap keys (etas are never NaN).
#[derive(Clone, Copy, PartialEq)]
struct EtaKey(f64);

impl Eq for EtaKey {}

impl PartialOrd for EtaKey {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for EtaKey {
    fn cmp(&self, other: &Self) -> Ordering {
        self.0.partial_cmp(&other.0).unwrap_or(Ordering::Equal)
    }
}

/// Cached resource-demand subtotal for one VM (or the fold of a host's
/// VMs).  `mips` is the fair-share-capped CPU demand (`vm_demand`);
/// ram/disk/bw are plain sums of resident task demand.
///
/// Bit-exactness contract: a VM's subtotal is always **recomputed from
/// scratch** with the reference arithmetic when its task set changes
/// (never adjusted by ±delta, which would drift under float
/// non-associativity), and a host's aggregate is re-folded over
/// `host.vms` order — the exact grouping the reference scans use.
#[derive(Clone, Copy, Default, PartialEq, Debug)]
struct ResLoad {
    mips: f64,
    ram_gb: f64,
    disk_gb: f64,
    bw_kbps: f64,
}

/// Entity storage + derived execution rates.
pub struct World {
    pub now: f64,
    pub hosts: Vec<Host>,
    pub vms: Vec<Vm>,
    tasks: Vec<Task>,
    jobs: Vec<Job>,
    /// Reserved-utilization knob (Fig. 6/8 sweep).
    pub reserved_util: f64,
    /// Per-task execution rate in MI/s (slowdown already applied);
    /// recomputed lazily from the dirty-host set.  Entries are valid only
    /// when their epoch stamp matches the current epoch — this avoids the
    /// O(total) zero-fill the seed engine paid on every recompute.  In
    /// indexed mode the epoch never moves (host-local recompute stamps the
    /// current epoch and invalidates by writing stamp 0, which is below
    /// the initial epoch); only the reference full pass bumps it.
    rates: Vec<f64>,
    rate_epoch: Vec<u64>,
    epoch: u64,
    /// Hosts whose resident rates are stale (DESIGN.md §11): every
    /// rate-affecting mutation marks only the host(s) it touched, and
    /// `recompute_dirty_hosts` re-runs the exact reference arithmetic for
    /// just those hosts.  `all_dirty` is the coarse fallback
    /// (`mark_rates_dirty`, and the only flavor reference mode uses — it
    /// keeps the seed's global recompute alive as the oracle).
    dirty_hosts: IdSet,
    all_dirty: bool,
    /// Hosts that were down at their last recompute: their residents carry
    /// no rate.  Matching the seed semantics — where recovery alone never
    /// triggers a recompute — they are re-rated only when the *next*
    /// recompute (caused by some other dirty event) observes them up.
    down_stale: IdSet,
    /// Latest raw M_H snapshot (set by the coordinator's feature extractor
    /// each interval; consumed by job-submission generative sampling).
    pub latest_m_h: Vec<f32>,
    /// Completed-task log for metrics: (task, completion_time).
    pub completed_log: Vec<TaskId>,
    /// Parity/debug mode: answer queries via the seed engine's O(total)
    /// full scans instead of the indexes.
    reference_scans: bool,
    // ------------------------------------------------ incremental indexes
    pending_set: IdSet,
    running_set: IdSet,
    held_set: IdSet,
    active_job_set: IdSet,
    /// Tasks in an active state (pending/running/held) per job.
    job_active_tasks: Vec<usize>,
    /// Active speculative copies, fleet-wide.
    live_clones: usize,
    /// original task → its (single) live speculative clone.
    active_clone: HashMap<TaskId, TaskId>,
    /// Min-heap of (projected absolute finish time, task, generation) over
    /// running tasks with positive rate.  Never cleared wholesale: each
    /// host-local recompute pushes fresh entries (with a bumped per-task
    /// generation stamp) for the tasks it re-rated, and consumers
    /// pop-and-discard entries whose stamp no longer matches `heap_gen` —
    /// the same lazy-invalidation discipline as the §9 availability wake
    /// heap.  Etas are time-invariant under constant rates, and are always
    /// re-derived from live task state at the peek site.
    finish_heap: BinaryHeap<Reverse<(EtaKey, TaskId, u64)>>,
    /// Current finish-heap generation per task; bumped on every re-rate
    /// and on unplacement, so older heap entries become stale.
    heap_gen: Vec<u64>,
    // --------------------------------------------- load accounting (§9)
    /// Per-VM cached demand subtotals, refreshed whenever the VM's task
    /// set changes (place/complete/kill/reset/hold-release).
    vm_load: Vec<ResLoad>,
    /// Per-host fold of its VMs' subtotals in `host.vms` order.
    host_load: Vec<ResLoad>,
    /// Per-host resident-task counter (`host_task_count` in O(1)).
    host_tasks: Vec<usize>,
    // ------------------------------------------- availability index (§9)
    /// VMs currently placeable (`vm_available`): ready and on an up host.
    avail_set: IdSet,
    /// `avail_set` in ascending id order — the exact candidate order of
    /// the reference `0..vms.len()` filter scan.  Rebuilt only when the
    /// set changed (`avail_dirty`), so steady-state queries are O(1).
    avail_sorted: Vec<VmId>,
    avail_dirty: bool,
    /// Min-heap of (wake time, vm) for VMs that left the available set:
    /// wake = max(ready_at, down_until).  Popped as `now` advances.
    /// Duplicates are allowed (a VM hit by several faults pushes several
    /// entries); stale pops are filtered against live state.
    suspend_heap: BinaryHeap<Reverse<(EtaKey, VmId)>>,
    // ------------------------------------------------- observability (§10)
    /// Structured event sink (sim/trace.rs): every state transition above
    /// records through it.  Off by default — one predicted branch per
    /// site; install with [`World::set_trace`].
    trace: TraceSink,
}

impl World {
    /// Build the PM fleet + VMs from config.
    pub fn new(cfg: &SimConfig) -> World {
        let mut hosts = Vec::new();
        let mut vms = Vec::new();
        for (type_idx, (&count, ty)) in cfg.pm_counts.iter().zip(&cfg.pm_types).enumerate() {
            for _ in 0..count {
                let hid = hosts.len();
                let mut host = Host {
                    id: hid,
                    type_idx,
                    mips_total: ty.mips_per_core * ty.cores as f64,
                    ram_gb: ty.ram_gb,
                    disk_gb: ty.disk_gb,
                    bw_kbps: ty.bw_kbps,
                    power_idle_w: ty.power_idle_w,
                    power_peak_w: ty.power_peak_w,
                    cost_per_interval: ty.cost_per_interval,
                    vms: Vec::new(),
                    down_until: None,
                    straggler_ema: 0.0,
                    background_load: 0.0,
                };
                for _ in 0..ty.vms_per_pm {
                    let vid = vms.len();
                    host.vms.push(vid);
                    vms.push(Vm {
                        id: vid,
                        host: hid,
                        mips: host.mips_total / ty.vms_per_pm as f64,
                        ram_gb: ty.ram_gb / ty.vms_per_pm as f64,
                        tasks: Vec::new(),
                        ready_at: 0.0,
                    });
                }
                hosts.push(host);
            }
        }
        // At t = 0 every VM is ready (`ready_at == 0.0`) on an up host,
        // so the availability index starts full.
        let n_vms = vms.len();
        let n_hosts = hosts.len();
        let mut avail_set = IdSet::default();
        for v in 0..n_vms {
            avail_set.insert(v);
        }
        World {
            now: 0.0,
            hosts,
            vms,
            tasks: Vec::new(),
            jobs: Vec::new(),
            reserved_util: cfg.reserved_util,
            rates: Vec::new(),
            rate_epoch: Vec::new(),
            epoch: 1,
            dirty_hosts: IdSet::default(),
            all_dirty: true,
            down_stale: IdSet::default(),
            latest_m_h: Vec::new(),
            completed_log: Vec::new(),
            reference_scans: cfg.reference_scans,
            pending_set: IdSet::default(),
            running_set: IdSet::default(),
            held_set: IdSet::default(),
            active_job_set: IdSet::default(),
            job_active_tasks: Vec::new(),
            live_clones: 0,
            active_clone: HashMap::new(),
            finish_heap: BinaryHeap::new(),
            heap_gen: Vec::new(),
            vm_load: vec![ResLoad::default(); n_vms],
            host_load: vec![ResLoad::default(); n_hosts],
            host_tasks: vec![0; n_hosts],
            avail_set,
            avail_sorted: (0..n_vms).collect(),
            avail_dirty: false,
            suspend_heap: BinaryHeap::new(),
            trace: TraceSink::default(),
        }
    }

    // -------------------------------------------------------- observability

    /// Install an event sink; subsequent state transitions are recorded.
    pub fn set_trace(&mut self, sink: TraceSink) {
        self.trace = sink;
    }

    /// Remove and return the sink (leaves tracing off).
    pub fn take_trace(&mut self) -> TraceSink {
        std::mem::take(&mut self.trace)
    }

    /// Events collected so far (in-memory sinks; empty otherwise).
    pub fn trace_events(&self) -> &[Event] {
        self.trace.events()
    }

    /// Record an event through the sink.  The closure runs only when
    /// tracing is enabled; it may capture any non-`World` state (the
    /// engine records decision events through this without borrowing the
    /// rest of the world).
    #[inline(always)]
    pub fn trace_record(&mut self, f: impl FnOnce() -> Event) {
        self.trace.record(f);
    }

    // ------------------------------------------------------------ registry

    /// Register a new task (id must be `n_tasks()`); indexes it by state.
    pub fn add_task(&mut self, t: Task) -> TaskId {
        let id = self.tasks.len();
        debug_assert_eq!(t.id, id, "task ids are dense");
        if t.job >= self.job_active_tasks.len() {
            self.job_active_tasks.resize(t.job + 1, 0);
        }
        let job = t.job;
        let active = t.is_active();
        let spec_of = t.speculative_of;
        let now = self.now;
        let submit_t = t.submit_t;
        let life = match t.state {
            TaskState::Pending => LifeState::Pending,
            TaskState::Running => LifeState::Running,
            TaskState::Held { .. } => LifeState::Held,
            TaskState::Completed { .. } | TaskState::Killed => LifeState::Done,
        };
        self.trace.record(|| Event::TaskAdmit {
            t: now,
            task: id,
            job,
            submit_t,
            speculative_of: spec_of,
            state: life,
        });
        self.tasks.push(t);
        // Per-task rate/heap bookkeeping stays dense with the arena, so
        // targeted invalidation never has to bounds-check or resize.
        self.rates.push(0.0);
        self.rate_epoch.push(0);
        self.heap_gen.push(0);
        if active {
            self.job_active_tasks[job] += 1;
            if let Some(orig) = spec_of {
                debug_assert!(
                    !self.active_clone.contains_key(&orig),
                    "task {orig} already has a live clone"
                );
                self.live_clones += 1;
                self.active_clone.insert(orig, id);
            }
        }
        self.index_enter_state(id);
        id
    }

    /// Register a new job (id must be `n_jobs()`).
    pub fn add_job(&mut self, j: Job) -> JobId {
        let id = self.jobs.len();
        debug_assert_eq!(j.id, id, "job ids are dense");
        if id >= self.job_active_tasks.len() {
            self.job_active_tasks.resize(id + 1, 0);
        }
        let active = j.is_active();
        let now = self.now;
        self.trace.record(|| Event::JobAdmit {
            t: now,
            job: id,
            tasks: j.tasks.clone(),
            deadline_driven: j.deadline_driven,
            sla_weight: j.sla_weight,
        });
        self.jobs.push(j);
        if active {
            self.active_job_set.insert(id);
        }
        id
    }

    /// Mark a job done at the current time (all tasks completed).
    pub fn finish_job(&mut self, job: JobId) {
        if self.jobs[job].is_active() {
            self.jobs[job].state = JobState::Done { t: self.now };
            self.active_job_set.remove(job);
            let now = self.now;
            self.trace.record(|| Event::JobDone { t: now, job });
        }
    }

    /// Record a mitigation action against a task (prediction scoring).
    pub fn mark_mitigated(&mut self, task: TaskId) {
        self.tasks[task].mitigated = true;
    }

    /// Set the ground-truth Pareto parameters sampled at submission.
    pub fn set_job_ground_truth(&mut self, job: JobId, alpha: f64, beta: f64) {
        self.jobs[job].true_alpha = alpha;
        self.jobs[job].true_beta = beta;
    }

    /// Set a job's absolute SLA deadline.
    pub fn set_job_sla_deadline(&mut self, job: JobId, deadline: f64) {
        self.jobs[job].sla_deadline = deadline;
        let now = self.now;
        self.trace.record(|| Event::JobSla { t: now, job, deadline });
    }

    fn index_enter_state(&mut self, id: TaskId) {
        match self.tasks[id].state {
            TaskState::Pending => {
                self.pending_set.insert(id);
            }
            TaskState::Running => {
                self.running_set.insert(id);
            }
            TaskState::Held { .. } => {
                self.held_set.insert(id);
            }
            _ => {}
        }
    }

    fn index_leave_state(&mut self, id: TaskId) {
        match self.tasks[id].state {
            TaskState::Pending => {
                self.pending_set.remove(id);
            }
            TaskState::Running => {
                self.running_set.remove(id);
            }
            TaskState::Held { .. } => {
                self.held_set.remove(id);
            }
            _ => {}
        }
    }

    /// The single choke point for task state changes: keeps the membership
    /// sets, per-job counters and clone map consistent.
    fn set_task_state(&mut self, id: TaskId, state: TaskState) {
        let was_active = self.tasks[id].is_active();
        self.index_leave_state(id);
        self.tasks[id].state = state;
        self.index_enter_state(id);
        let is_active = self.tasks[id].is_active();
        if was_active == is_active {
            return;
        }
        let job = self.tasks[id].job;
        if is_active {
            self.job_active_tasks[job] += 1;
        } else {
            self.job_active_tasks[job] -= 1;
        }
        if let Some(orig) = self.tasks[id].speculative_of {
            if is_active {
                debug_assert!(!self.active_clone.contains_key(&orig));
                self.live_clones += 1;
                self.active_clone.insert(orig, id);
            } else {
                self.live_clones -= 1;
                if self.active_clone.get(&orig) == Some(&id) {
                    self.active_clone.remove(&orig);
                }
            }
        }
    }

    // ------------------------------------------------------------ queries

    /// Read a task.
    pub fn task(&self, id: TaskId) -> &Task {
        &self.tasks[id]
    }

    /// Read a job.
    pub fn job(&self, id: JobId) -> &Job {
        &self.jobs[id]
    }

    /// Total tasks ever created (dense id space).
    pub fn n_tasks(&self) -> usize {
        self.tasks.len()
    }

    /// Total jobs ever created (dense id space).
    pub fn n_jobs(&self) -> usize {
        self.jobs.len()
    }

    /// Pending tasks, ascending id (the placement queue).
    pub fn pending(&self) -> Vec<TaskId> {
        if self.reference_scans {
            return self
                .tasks
                .iter()
                .filter(|t| t.state == TaskState::Pending)
                .map(|t| t.id)
                .collect();
        }
        self.pending_set.sorted()
    }

    /// Running tasks, ascending id.
    pub fn running(&self) -> Vec<TaskId> {
        if self.reference_scans {
            return self.tasks.iter().filter(|t| t.is_running()).map(|t| t.id).collect();
        }
        self.running_set.sorted()
    }

    /// Held (Wrangler-delayed) tasks, ascending id.
    pub fn held(&self) -> Vec<TaskId> {
        if self.reference_scans {
            return self
                .tasks
                .iter()
                .filter(|t| matches!(t.state, TaskState::Held { .. }))
                .map(|t| t.id)
                .collect();
        }
        self.held_set.sorted()
    }

    /// Jobs still active, ascending id.
    pub fn active_jobs(&self) -> Vec<JobId> {
        if self.reference_scans {
            return self.jobs.iter().filter(|j| j.is_active()).map(|j| j.id).collect();
        }
        self.active_job_set.sorted()
    }

    /// Whether any job is still active (the drain-loop check).
    pub fn has_active_jobs(&self) -> bool {
        if self.reference_scans {
            return self.jobs.iter().any(|j| j.is_active());
        }
        !self.active_job_set.is_empty()
    }

    /// Number of active jobs.
    pub fn active_job_count(&self) -> usize {
        if self.reference_scans {
            return self.jobs.iter().filter(|j| j.is_active()).count();
        }
        self.active_job_set.len()
    }

    /// Number of tasks in an active state (pending/running/held).
    pub fn active_task_count(&self) -> usize {
        if self.reference_scans {
            return self.tasks.iter().filter(|t| t.is_active()).count();
        }
        self.pending_set.len() + self.running_set.len() + self.held_set.len()
    }

    /// Active tasks of one job (counter-backed fast path for emptiness).
    /// Counts every task carrying the job id — **including live
    /// speculative clones** — unlike `active_tasks`, which walks the
    /// job's original task list only.
    pub fn job_active_count(&self, job: JobId) -> usize {
        self.job_active_tasks.get(job).copied().unwrap_or(0)
    }

    /// Live speculative copies fleet-wide (the baselines' clone budgets).
    pub fn live_clone_count(&self) -> usize {
        if self.reference_scans {
            return self
                .tasks
                .iter()
                .filter(|t| t.speculative_of.is_some() && t.is_active())
                .count();
        }
        self.live_clones
    }

    /// The live speculative clone of `task`, if any.
    pub fn clone_of(&self, task: TaskId) -> Option<TaskId> {
        if self.reference_scans {
            // Clones are appended after their original; scan backwards.
            return self
                .tasks
                .iter()
                .rev()
                .find(|t| t.speculative_of == Some(task) && t.is_active())
                .map(|t| t.id);
        }
        self.active_clone.get(&task).copied()
    }

    /// All tasks, including dead ones.  O(total) — conservation tests and
    /// debugging only; hot-path code must use the set accessors above.
    pub fn debug_tasks(&self) -> &[Task] {
        &self.tasks
    }

    /// All jobs.  O(total) — tests and debugging only.
    pub fn debug_jobs(&self) -> &[Job] {
        &self.jobs
    }

    /// Active (pending/running/held) tasks of a job — **originals only**
    /// (speculative clones are not in `Job::tasks`); see
    /// `job_active_count` for the clone-inclusive counter.
    pub fn active_tasks(&self, job: JobId) -> Vec<TaskId> {
        self.jobs[job]
            .tasks
            .iter()
            .copied()
            .filter(|&t| self.tasks[t].is_active())
            .collect()
    }

    /// Completed tasks of a job (non-speculative originals count once).
    pub fn completed_tasks(&self, job: JobId) -> usize {
        self.jobs[job]
            .tasks
            .iter()
            .filter(|&&t| matches!(self.tasks[t].state, TaskState::Completed { .. }))
            .count()
    }

    /// Whether a VM can currently accept work.
    pub fn vm_available(&self, vm: VmId) -> bool {
        let v = &self.vms[vm];
        v.ready_at <= self.now && self.hosts[v.host].is_up(self.now)
    }

    /// Sum of task MIPS demand currently on a VM (capped per task by fair
    /// share).  O(1) via the cached subtotal; reference mode recomputes.
    fn vm_demand(&self, vm: VmId) -> f64 {
        if self.reference_scans {
            let v = &self.vms[vm];
            let n = v.tasks.len().max(1) as f64;
            let fair = v.mips / n;
            return v
                .tasks
                .iter()
                .map(|&t| self.tasks[t].demand.mips.min(fair).max(1.0))
                .sum();
        }
        self.vm_load[vm].mips
    }

    /// Host CPU utilization in [0, 1] including background + reserved load.
    /// O(1) via the per-host aggregate; reference mode re-sums per VM.
    pub fn host_cpu_util(&self, host: HostId) -> f64 {
        let h = &self.hosts[host];
        if !h.is_up(self.now) {
            return 0.0;
        }
        let demand: f64 = if self.reference_scans {
            h.vms.iter().map(|&v| self.vm_demand(v)).sum()
        } else {
            self.host_load[host].mips
        };
        (demand / h.mips_total + h.background_load + self.reserved_util).min(1.0)
    }

    /// Host RAM utilization in [0, 1].  Both modes group the sum per VM
    /// (subtotal-then-fold) so the arithmetic is bitwise shared.
    pub fn host_ram_util(&self, host: HostId) -> f64 {
        let h = &self.hosts[host];
        let used: f64 = if self.reference_scans {
            // Grouped per VM (not one flat sum over all host tasks) so the
            // fold order matches the indexed subtotal-then-aggregate path.
            h.vms
                .iter()
                .map(|&v| {
                    self.vms[v].tasks.iter().map(|&t| self.tasks[t].demand.ram_gb).sum::<f64>()
                })
                .sum()
        } else {
            self.host_load[host].ram_gb
        };
        (used / h.ram_gb + 0.5 * h.background_load + 0.5 * self.reserved_util).min(1.0)
    }

    /// Host disk utilization in [0, 1].
    pub fn host_disk_util(&self, host: HostId) -> f64 {
        let h = &self.hosts[host];
        let used: f64 = if self.reference_scans {
            h.vms
                .iter()
                .map(|&v| {
                    self.vms[v].tasks.iter().map(|&t| self.tasks[t].demand.disk_gb).sum::<f64>()
                })
                .sum()
        } else {
            self.host_load[host].disk_gb
        };
        (used / h.disk_gb + 0.3 * self.reserved_util).min(1.0)
    }

    /// Host network utilization in [0, 1].
    pub fn host_bw_util(&self, host: HostId) -> f64 {
        let h = &self.hosts[host];
        let used: f64 = if self.reference_scans {
            h.vms
                .iter()
                .map(|&v| {
                    self.vms[v].tasks.iter().map(|&t| self.tasks[t].demand.bw_kbps).sum::<f64>()
                })
                .sum()
        } else {
            self.host_load[host].bw_kbps
        };
        (used / h.bw_kbps.max(1e-9) + 0.3 * self.reserved_util).min(1.0)
    }

    /// Number of resident tasks on a host (counter-backed).
    pub fn host_task_count(&self, host: HostId) -> usize {
        if self.reference_scans {
            return self.hosts[host].vms.iter().map(|&v| self.vms[v].tasks.len()).sum();
        }
        self.host_tasks[host]
    }

    // ------------------------------------------------- load accounting

    /// Reference-arithmetic demand subtotal of one VM: fair-share-capped
    /// MIPS plus plain ram/disk/bw sums, folded in `vm.tasks` order.
    /// This is the **single definition** both modes share — the indexed
    /// caches are always produced by this exact fold.
    fn compute_vm_load(&self, vm: VmId) -> ResLoad {
        let v = &self.vms[vm];
        let n = v.tasks.len().max(1) as f64;
        let fair = v.mips / n;
        let mut l = ResLoad::default();
        for &t in &v.tasks {
            let d = &self.tasks[t].demand;
            l.mips += d.mips.min(fair).max(1.0);
            l.ram_gb += d.ram_gb;
            l.disk_gb += d.disk_gb;
            l.bw_kbps += d.bw_kbps;
        }
        l
    }

    /// Refresh one VM's cached subtotal and re-fold its host's aggregate
    /// (in `host.vms` order, matching the reference grouping bit for bit).
    /// Called on every task placement/detachment; O(tasks-on-vm +
    /// vms-on-host), independent of fleet size.
    fn refresh_vm_load(&mut self, vm: VmId) {
        self.vm_load[vm] = self.compute_vm_load(vm);
        let host = self.vms[vm].host;
        let mut agg = ResLoad::default();
        for &v in &self.hosts[host].vms {
            let l = &self.vm_load[v];
            agg.mips += l.mips;
            agg.ram_gb += l.ram_gb;
            agg.disk_gb += l.disk_gb;
            agg.bw_kbps += l.bw_kbps;
        }
        self.host_load[host] = agg;
    }

    // ----------------------------------------------- availability index

    /// Absolute time at which a VM (re)enters the available set: the later
    /// of its readiness and its host's recovery.  `<= now` iff available.
    fn vm_wake_time(&self, vm: VmId) -> f64 {
        let v = &self.vms[vm];
        v.ready_at.max(self.hosts[v.host].down_until.unwrap_or(f64::NEG_INFINITY))
    }

    /// Reconcile one VM's membership in the availability index with its
    /// live state; schedules a wake-up when it is currently unavailable.
    fn refresh_vm_availability(&mut self, vm: VmId) {
        if self.reference_scans {
            return;
        }
        if self.vm_available(vm) {
            if self.avail_set.insert(vm) {
                self.avail_dirty = true;
            }
        } else {
            if self.avail_set.remove(vm) {
                self.avail_dirty = true;
            }
            // Wake time is strictly in the future whenever the VM is
            // unavailable, so re-popping the same entry cannot loop.
            self.suspend_heap.push(Reverse((EtaKey(self.vm_wake_time(vm)), vm)));
        }
    }

    /// Rebuild the sorted candidate cache if membership changed.
    fn rebuild_avail_cache(&mut self) {
        if self.avail_dirty {
            self.avail_sorted = self.avail_set.sorted();
            self.avail_dirty = false;
        }
    }

    /// Pop matured wake-ups as `now` advances and re-admit their VMs.
    /// Stale entries (VM re-suspended with a later wake, or already
    /// re-admitted via an earlier duplicate) are filtered by re-checking
    /// live state.
    fn sync_availability(&mut self) {
        if self.reference_scans {
            return;
        }
        while let Some(&Reverse((EtaKey(wake), vm))) = self.suspend_heap.peek() {
            if wake > self.now {
                break;
            }
            self.suspend_heap.pop();
            if !self.avail_set.contains(vm) {
                self.refresh_vm_availability(vm);
            }
        }
        self.rebuild_avail_cache();
    }

    /// Take a host down until `until`, updating the availability index.
    /// All host up/down transitions must go through here (not by writing
    /// `down_until` directly) so the index cannot drift.
    // Index loop splits the borrow of `hosts[host].vms` from the `&mut
    // self` availability refresh, as in `recompute_host`.
    #[allow(clippy::needless_range_loop)]
    pub fn set_host_down(&mut self, host: HostId, until: f64) {
        self.hosts[host].down_until = Some(until);
        self.mark_host_rates_dirty(host);
        if !self.reference_scans {
            for vi in 0..self.hosts[host].vms.len() {
                let vm = self.hosts[host].vms[vi];
                self.refresh_vm_availability(vm);
            }
            self.rebuild_avail_cache();
        }
    }

    /// Set a host's background load (the per-interval trace refresh),
    /// dirtying its rates only when the value actually changed (bitwise).
    /// All background-load writes must go through here so the dirty-host
    /// set cannot miss a rate change.
    pub fn set_background_load(&mut self, host: HostId, load: f64) {
        if self.hosts[host].background_load.to_bits() != load.to_bits() {
            self.hosts[host].background_load = load;
            self.mark_host_rates_dirty(host);
        }
    }

    /// Set a VM's readiness time, updating the availability index.
    pub fn set_vm_ready_at(&mut self, vm: VmId, ready_at: f64) {
        self.vms[vm].ready_at = ready_at;
        if !self.reference_scans {
            self.refresh_vm_availability(vm);
            self.rebuild_avail_cache();
        }
    }

    /// Currently placeable VMs in ascending id order — the scheduler
    /// candidate list.  Indexed mode borrows the cached slice (O(1) when
    /// availability is unchanged); reference mode materializes the seed's
    /// full filter scan.  Content and order are identical, so downstream
    /// RNG streams (Random/A3C sampling) cannot diverge between modes.
    pub fn available_vms(&self) -> Cow<'_, [VmId]> {
        if self.reference_scans {
            return Cow::Owned((0..self.vms.len()).filter(|&v| self.vm_available(v)).collect());
        }
        Cow::Borrowed(&self.avail_sorted)
    }

    // --------------------------------------------------------- placement

    /// Start (or restart) a task on a VM.  `slowdown` is the Pareto
    /// duration multiplier sampled by the caller from the job's
    /// ground-truth distribution.
    pub fn start_task(&mut self, task: TaskId, vm: VmId, slowdown: f64) {
        debug_assert!(self.tasks[task].vm.is_none(), "task already placed");
        self.set_task_state(task, TaskState::Running);
        let t = &mut self.tasks[task];
        t.vm = Some(vm);
        t.last_vm = Some(vm);
        t.slowdown = slowdown.max(1e-3);
        if t.first_start_t.is_none() {
            t.first_start_t = Some(self.now);
        }
        self.vms[vm].tasks.push(task);
        self.mark_host_rates_dirty(self.vms[vm].host);
        if !self.reference_scans {
            self.host_tasks[self.vms[vm].host] += 1;
            self.refresh_vm_load(vm);
        }
        let now = self.now;
        let sd = self.tasks[task].slowdown;
        self.trace.record(|| Event::TaskStart { t: now, task, vm, slowdown: sd });
    }

    /// Remove a task from its VM (completion, kill, restart).
    pub fn unplace_task(&mut self, task: TaskId) {
        if let Some(vm) = self.tasks[task].vm.take() {
            self.vms[vm].tasks.retain(|&t| t != task);
            self.mark_host_rates_dirty(self.vms[vm].host);
            // The detached task is no longer rated: the host-local
            // recompute will not revisit it, so invalidate its stamp here
            // and retire any finish-heap entry it still has.
            self.rate_epoch[task] = 0;
            self.heap_gen[task] += 1;
            if !self.reference_scans {
                self.host_tasks[self.vms[vm].host] -= 1;
                self.refresh_vm_load(vm);
            }
        }
    }

    /// Mark a task completed now and detach it.
    pub fn complete_task(&mut self, task: TaskId) {
        self.unplace_task(task);
        self.set_task_state(task, TaskState::Completed { t: self.now });
        self.tasks[task].remaining_mi = 0.0;
        self.completed_log.push(task);
        let now = self.now;
        self.trace.record(|| Event::TaskComplete { t: now, task });
    }

    /// Complete a task whose result arrived via its speculative clone: the
    /// logical task is done but this execution did not itself finish (it
    /// keeps its residual work and is not appended to the completion log).
    pub fn complete_superseded(&mut self, task: TaskId) {
        self.unplace_task(task);
        self.set_task_state(task, TaskState::Completed { t: self.now });
        let now = self.now;
        self.trace.record(|| Event::TaskSuperseded { t: now, task });
    }

    /// Kill a task (lost race / superseded) and detach it.
    pub fn kill_task(&mut self, task: TaskId) {
        self.unplace_task(task);
        self.set_task_state(task, TaskState::Killed);
        let now = self.now;
        self.trace.record(|| Event::TaskKill { t: now, task });
    }

    /// Reset a task to pending with full work (restart after fault/rerun);
    /// accumulates restart bookkeeping.
    pub fn reset_task(&mut self, task: TaskId, restart_penalty_s: f64) {
        self.unplace_task(task);
        self.set_task_state(task, TaskState::Pending);
        let t = &mut self.tasks[task];
        t.remaining_mi = t.length_mi;
        t.restarts += 1;
        t.restart_time += restart_penalty_s;
        let now = self.now;
        self.trace.record(|| Event::TaskReset { t: now, task, penalty_s: restart_penalty_s });
    }

    /// Put a pending task on hold until `until` (Wrangler-style delaying).
    pub fn hold_task(&mut self, task: TaskId, until: f64) -> bool {
        if self.tasks[task].state == TaskState::Pending {
            self.set_task_state(task, TaskState::Held { until });
            let now = self.now;
            self.trace.record(|| Event::TaskHold { t: now, task, until });
            true
        } else {
            false
        }
    }

    /// Release held tasks whose hold expired (back to Pending).
    pub fn release_expired_holds(&mut self) -> usize {
        let now = self.now;
        // Both modes share one expiry predicate; only the candidate id
        // source differs (full scan vs held set), so the parity contract
        // cannot drift if the epsilon or the Held match ever changes.
        let candidates: Vec<TaskId> = if self.reference_scans {
            (0..self.tasks.len()).collect()
        } else {
            self.held_set.sorted()
        };
        let expired: Vec<TaskId> = candidates
            .into_iter()
            .filter(|&t| match self.tasks[t].state {
                TaskState::Held { until } => now + 1e-9 >= until,
                _ => false,
            })
            .collect();
        for &t in &expired {
            self.set_task_state(t, TaskState::Pending);
            self.trace.record(|| Event::TaskRelease { t: now, task: t });
        }
        expired.len()
    }

    // ----------------------------------------------------- rate computation

    /// Whether any rate is stale (the old single `rates_dirty` bit).
    /// `down_stale` alone does **not** count: host recovery never triggers
    /// a recompute (seed semantics) — recovered hosts are swept up by the
    /// next recompute some other dirty event causes.
    fn rates_dirty(&self) -> bool {
        self.all_dirty || !self.dirty_hosts.is_empty()
    }

    /// Mark one host's resident rates stale.  Reference mode collapses to
    /// the seed's single dirty bit (global recompute).
    fn mark_host_rates_dirty(&mut self, host: HostId) {
        if self.reference_scans {
            self.all_dirty = true;
        } else {
            self.dirty_hosts.insert(host);
        }
    }

    /// Recompute stale rates before a rate-dependent query.  Reference
    /// mode runs the seed-faithful global pass; indexed mode re-rates only
    /// the dirty hosts.
    fn recompute_if_dirty(&mut self) {
        if !self.rates_dirty() {
            return;
        }
        if self.reference_scans {
            self.recompute_rates_reference();
        } else {
            self.recompute_dirty_hosts();
        }
    }

    /// Seed-faithful global recompute (reference mode only): O(total)
    /// zero-fill plus a full-fleet pass in host/VM/task order, bumping the
    /// validity epoch so every stamp from earlier passes goes stale.
    ///
    /// Model: each task's fair demand on its VM is
    /// `min(demand.mips, vm.mips / n_tasks)`; a host whose aggregate VM
    /// demand exceeds its effective capacity (after background + reserved
    /// load) scales every resident task proportionally — this is the
    /// resource-contention mechanism (Eq. 9's "overloaded" condition).
    // Index loops are deliberate: they split borrows across `hosts`/`vms`/
    // `tasks`/`rates` fields, which iterator chains cannot.
    #[allow(clippy::needless_range_loop)]
    fn recompute_rates_reference(&mut self) {
        self.epoch += 1;
        let epoch = self.epoch;
        // Seed-faithful O(total) zero-fill; the indexed path instead
        // invalidates by stamp so dead tasks cost nothing.
        for r in self.rates.iter_mut() {
            *r = 0.0;
        }
        // Reference mode answers `next_finish_time` by full scan, so it
        // must not pay (or rely on) heap upkeep.
        self.finish_heap.clear();
        for h in 0..self.hosts.len() {
            let host = &self.hosts[h];
            if !host.is_up(self.now) {
                continue;
            }
            let demand: f64 = host.vms.iter().map(|&v| self.vm_demand(v)).sum();
            if demand <= 0.0 {
                continue;
            }
            let capacity = host.effective_mips(self.reserved_util);
            let scale = (capacity / demand).min(1.0);
            for vi in 0..self.hosts[h].vms.len() {
                let v = self.hosts[h].vms[vi];
                let vm = &self.vms[v];
                let n = vm.tasks.len().max(1) as f64;
                let fair = vm.mips / n;
                for ti in 0..self.vms[v].tasks.len() {
                    let t = self.vms[v].tasks[ti];
                    let nominal = self.tasks[t].demand.mips.min(fair).max(1.0);
                    let rate = nominal * scale / self.tasks[t].slowdown;
                    self.rates[t] = rate;
                    self.rate_epoch[t] = epoch;
                }
            }
        }
        self.all_dirty = false;
        self.dirty_hosts.clear();
    }

    /// Host-local recompute (DESIGN.md §11): re-run the reference
    /// arithmetic for exactly the dirty hosts — plus recovered
    /// `down_stale` hosts — and push fresh generation-stamped finish-heap
    /// entries for their running residents.  Rates on untouched hosts (and
    /// their live heap entries) are left as the previous pass wrote them,
    /// which is bit-identical to what a full pass would write: the rate
    /// arithmetic reads only host-local state, and the `host_load[h]`
    /// demand aggregate is maintained bitwise equal to the reference
    /// per-VM fold (§9).
    fn recompute_dirty_hosts(&mut self) {
        if self.all_dirty {
            for h in 0..self.hosts.len() {
                self.recompute_host(h);
            }
        } else {
            // Dirty hosts plus recovered hosts whose residents still carry
            // stale zero rates; ascending id — the full-pass host order.
            let mut targets = self.dirty_hosts.dense.clone();
            for i in 0..self.down_stale.dense.len() {
                let h = self.down_stale.dense[i];
                if self.hosts[h].is_up(self.now) && !self.dirty_hosts.contains(h) {
                    targets.push(h);
                }
            }
            targets.sort_unstable();
            for h in targets {
                self.recompute_host(h);
            }
        }
        self.all_dirty = false;
        self.dirty_hosts.clear();
        self.compact_finish_heap();
    }

    /// Re-rate one host with the exact reference arithmetic (same
    /// expressions, same `host.vms`/`vm.tasks` fold order).  Down hosts
    /// contribute no rate: their residents' stamps are invalidated and the
    /// host parks in `down_stale` until a later recompute sees it up.
    #[allow(clippy::needless_range_loop)]
    fn recompute_host(&mut self, h: HostId) {
        if !self.hosts[h].is_up(self.now) {
            for vi in 0..self.hosts[h].vms.len() {
                let v = self.hosts[h].vms[vi];
                for ti in 0..self.vms[v].tasks.len() {
                    let t = self.vms[v].tasks[ti];
                    self.rate_epoch[t] = 0;
                    self.heap_gen[t] += 1;
                }
            }
            self.down_stale.insert(h);
            return;
        }
        self.down_stale.remove(h);
        // §9 aggregate: bitwise equal to the reference per-VM demand fold.
        let demand = self.host_load[h].mips;
        if demand <= 0.0 {
            // No residents (every resident demands >= 1 MIPS), so there is
            // nothing to re-rate or invalidate.
            return;
        }
        let capacity = self.hosts[h].effective_mips(self.reserved_util);
        let scale = (capacity / demand).min(1.0);
        let now = self.now;
        let epoch = self.epoch;
        for vi in 0..self.hosts[h].vms.len() {
            let v = self.hosts[h].vms[vi];
            let n = self.vms[v].tasks.len().max(1) as f64;
            let fair = self.vms[v].mips / n;
            for ti in 0..self.vms[v].tasks.len() {
                let t = self.vms[v].tasks[ti];
                let nominal = self.tasks[t].demand.mips.min(fair).max(1.0);
                let rate = nominal * scale / self.tasks[t].slowdown;
                self.rates[t] = rate;
                self.rate_epoch[t] = epoch;
                if rate > 0.0 && self.tasks[t].is_running() {
                    self.heap_gen[t] += 1;
                    let gen = self.heap_gen[t];
                    self.finish_heap
                        .push(Reverse((EtaKey(now + self.tasks[t].remaining_mi / rate), t, gen)));
                }
            }
        }
    }

    /// Deterministic size bound on the lazily-invalidated finish heap:
    /// when stale entries outnumber live ones ~4:1, rebuild from the live
    /// set (stored etas kept verbatim).  Triggered by sim state only —
    /// never wall clock — so replays and the parity contract are
    /// unaffected.
    fn compact_finish_heap(&mut self) {
        if self.finish_heap.len() <= 64 + 4 * self.running_set.len() {
            return;
        }
        let live: Vec<_> = std::mem::take(&mut self.finish_heap)
            .into_vec()
            .into_iter()
            .filter(|&Reverse((_, t, gen))| {
                self.heap_gen[t] == gen && self.tasks[t].is_running() && self.rate_of(t) > 0.0
            })
            .collect();
        self.finish_heap = BinaryHeap::from(live);
    }

    /// Rate of a task under the current epoch (0 if not computed = idle,
    /// dead, or on a down host).
    fn rate_of(&self, task: TaskId) -> f64 {
        if task < self.rates.len() && self.rate_epoch[task] == self.epoch {
            self.rates[task]
        } else {
            0.0
        }
    }

    /// Force a full rate recomputation on next use.  The typed mutators
    /// self-mark the hosts they touch, so this coarse fallback is only for
    /// callers that mutated rate inputs outside the typed surface.
    pub fn mark_rates_dirty(&mut self) {
        self.all_dirty = true;
    }

    /// Current rate of a task (MI/s).
    pub fn task_rate(&mut self, task: TaskId) -> f64 {
        self.recompute_if_dirty();
        self.rate_of(task)
    }

    /// Earliest projected completion time among running tasks.
    ///
    /// Indexed mode peeks the lazy finish-time heap (O(1) when rates are
    /// clean); the returned eta is always re-derived from the task's live
    /// remaining work so both modes share one arithmetic definition (and
    /// `advance` is guaranteed to make progress — a cached value could
    /// land an ulp short of the completion threshold and stall the loop).
    ///
    /// Caveat: the heap orders by etas cached at recompute time.  Etas are
    /// time-invariant under clean rates in exact arithmetic, but if time
    /// advanced since the rebuild (fault events that do not touch rates),
    /// two etas within a few ulps of each other could rank differently
    /// than a fresh scan.  Candidate etas derive from independent
    /// continuous draws (Pareto slowdowns, normal task sizes), so such
    /// near-ties have effectively zero measure; the parity suite runs both
    /// modes across seeds/fault-rates to back this empirically.
    #[allow(clippy::needless_range_loop)]
    pub fn next_finish_time(&mut self) -> Option<f64> {
        self.recompute_if_dirty();
        if self.reference_scans {
            let now = self.now;
            let mut best: Option<f64> = None;
            for t in 0..self.tasks.len() {
                if self.tasks[t].is_running() {
                    let rate = self.rate_of(t);
                    if rate > 0.0 {
                        let eta = now + self.tasks[t].remaining_mi / rate;
                        best = Some(match best {
                            Some(b) => b.min(eta),
                            None => eta,
                        });
                    }
                }
            }
            return best;
        }
        // Lazy invalidation: discard entries whose generation stamp is
        // stale (task re-rated, unplaced, or its host went down since the
        // push); the first live entry is the minimum.
        while let Some(&Reverse((_, t, gen))) = self.finish_heap.peek() {
            if self.heap_gen[t] == gen && self.tasks[t].is_running() {
                let rate = self.rate_of(t);
                if rate > 0.0 {
                    return Some(self.now + self.tasks[t].remaining_mi / rate);
                }
            }
            self.finish_heap.pop();
        }
        None
    }

    /// Advance simulated time to `to`, consuming work on all running
    /// tasks.  Returns tasks whose remaining work reached zero, in
    /// ascending id order.
    #[allow(clippy::needless_range_loop)]
    pub fn advance(&mut self, to: f64) -> Vec<TaskId> {
        debug_assert!(to >= self.now - 1e-9, "time must be monotone");
        self.recompute_if_dirty();
        let dt = (to - self.now).max(0.0);
        self.now = to;
        // Re-admit VMs whose ready/recovery time has now passed.  `now`
        // only moves here, so the availability index is exact at every
        // query point.
        self.sync_availability();
        if dt == 0.0 {
            return Vec::new();
        }
        let mut done = Vec::new();
        if self.reference_scans {
            for t in 0..self.tasks.len() {
                if self.tasks[t].is_running() {
                    let rate = self.rate_of(t);
                    if rate > 0.0 {
                        self.tasks[t].remaining_mi -= rate * dt;
                        if self.tasks[t].remaining_mi <= 1e-6 {
                            done.push(t);
                        }
                    }
                }
            }
        } else {
            for i in 0..self.running_set.dense.len() {
                let t = self.running_set.dense[i];
                let rate = self.rate_of(t);
                if rate > 0.0 {
                    self.tasks[t].remaining_mi -= rate * dt;
                    if self.tasks[t].remaining_mi <= 1e-6 {
                        done.push(t);
                    }
                }
            }
            done.sort_unstable();
        }
        done
    }

    /// Update the per-host straggler moving average (Alg. 1's node-choice
    /// signal): called when a task is classified at completion.
    pub fn note_straggler(&mut self, host: HostId, was_straggler: bool) {
        let h = &mut self.hosts[host];
        let x = if was_straggler { 1.0 } else { 0.0 };
        h.straggler_ema = 0.8 * h.straggler_ema + 0.2 * x;
    }

    /// Pick the up-VM on the host with the lowest straggler moving average
    /// (the paper's mitigation target choice), breaking ties toward
    /// unloaded hosts so mitigation does not itself create contention.
    /// Candidates come from the availability index (ascending id — the
    /// order the pre-index `0..vms.len()` filter produced), and the
    /// per-host key reads the O(1) aggregates.
    pub fn best_mitigation_vm(&self, exclude_host: Option<HostId>) -> Option<VmId> {
        let mut best: Option<((i64, i64, usize), VmId)> = None;
        for &v in self.available_vms().iter() {
            let host = self.vms[v].host;
            if Some(host) == exclude_host {
                continue;
            }
            // Quantized straggler EMA first (the paper's signal), then
            // host CPU utilization, then VM queue depth.
            let key = (
                (self.hosts[host].straggler_ema * 10.0) as i64,
                (self.host_cpu_util(host) * 20.0) as i64,
                self.vms[v].tasks.len(),
            );
            if best.map(|(b, _)| key < b).unwrap_or(true) {
                best = Some((key, v));
            }
        }
        best.map(|(_, v)| v)
    }

    /// Fleet-wide maxima used for feature normalization.
    pub fn fleet_max(&self) -> (f64, f64, f64, f64) {
        let mut m = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
        for h in &self.hosts {
            m.0 = m.0.max(h.mips_total);
            m.1 = m.1.max(h.ram_gb);
            m.2 = m.2.max(h.disk_gb);
            m.3 = m.3.max(h.bw_kbps);
        }
        m
    }

    // ---------------------------------------------------------- invariants

    /// Cross-check every incremental index against a from-scratch O(total)
    /// recount.  Panics (with a description) on any drift.  Test/debug
    /// only — this is intentionally the full scan the indexes replace.
    pub fn assert_consistent(&self) {
        let mut pend = Vec::new();
        let mut run = Vec::new();
        let mut held = Vec::new();
        let mut job_active = vec![0usize; self.job_active_tasks.len()];
        let mut clones = 0usize;
        let mut clone_map: HashMap<TaskId, TaskId> = HashMap::new();
        for t in &self.tasks {
            match t.state {
                TaskState::Pending => pend.push(t.id),
                TaskState::Running => run.push(t.id),
                TaskState::Held { .. } => held.push(t.id),
                _ => {}
            }
            if t.is_active() {
                if t.job >= job_active.len() {
                    job_active.resize(t.job + 1, 0);
                }
                job_active[t.job] += 1;
                if let Some(orig) = t.speculative_of {
                    clones += 1;
                    let prev = clone_map.insert(orig, t.id);
                    assert!(prev.is_none(), "two live clones of task {orig}");
                }
            }
        }
        assert_eq!(self.pending_set.sorted(), pend, "pending set drift");
        assert_eq!(self.running_set.sorted(), run, "running set drift");
        assert_eq!(self.held_set.sorted(), held, "held set drift");
        assert_eq!(self.live_clones, clones, "live-clone counter drift");
        assert_eq!(self.active_clone.len(), clone_map.len(), "clone map size drift");
        for (orig, clone) in &clone_map {
            assert_eq!(
                self.active_clone.get(orig),
                Some(clone),
                "clone map drift for task {orig}"
            );
        }
        for (j, &n) in job_active.iter().enumerate() {
            assert_eq!(
                self.job_active_tasks.get(j).copied().unwrap_or(0),
                n,
                "active-task counter drift for job {j}"
            );
        }
        let active_jobs: Vec<JobId> =
            self.jobs.iter().filter(|j| j.is_active()).map(|j| j.id).collect();
        assert_eq!(self.active_job_set.sorted(), active_jobs, "active-job set drift");
        for t in &self.tasks {
            match t.state {
                TaskState::Running => {
                    let vm = t.vm.expect("running task must be placed");
                    assert_eq!(
                        self.vms[vm].tasks.iter().filter(|&&x| x == t.id).count(),
                        1,
                        "task {} not resident exactly once on vm {vm}",
                        t.id
                    );
                }
                _ => {
                    assert!(t.vm.is_none(), "non-running task {} still placed", t.id);
                    assert_eq!(self.rate_of(t.id), 0.0, "unplaced task {} still rated", t.id);
                }
            }
        }
        if !self.rates_dirty() && !self.reference_scans {
            // Live heap entries (generation stamp current) must cover
            // exactly the running-with-rate set, with no duplicates.
            let mut heap_ids: Vec<TaskId> = self
                .finish_heap
                .iter()
                .filter(|Reverse((_, t, gen))| self.heap_gen[*t] == *gen)
                .map(|Reverse((_, t, _))| *t)
                .collect();
            heap_ids.sort_unstable();
            assert!(
                heap_ids.windows(2).all(|p| p[0] != p[1]),
                "duplicate live finish-heap entries"
            );
            let expect: Vec<TaskId> =
                run.iter().copied().filter(|&t| self.rate_of(t) > 0.0).collect();
            assert_eq!(heap_ids, expect, "finish-heap membership drift");
            // Tentpole invariant (§11): every maintained rate must equal a
            // from-scratch reference recompute, bitwise.  Hosts parked in
            // `down_stale` (down, or recovered but not yet re-rated)
            // instead carry no rate at all.
            for h in 0..self.hosts.len() {
                if !self.hosts[h].is_up(self.now) {
                    assert!(
                        self.down_stale.contains(h),
                        "down host {h} missing from down_stale"
                    );
                }
                if self.down_stale.contains(h) {
                    for &v in &self.hosts[h].vms {
                        for &t in &self.vms[v].tasks {
                            assert_eq!(
                                self.rate_of(t),
                                0.0,
                                "stale-down host {h}: task {t} still rated"
                            );
                        }
                    }
                    continue;
                }
                let demand: f64 =
                    self.hosts[h].vms.iter().map(|&v| self.compute_vm_load(v).mips).sum();
                if demand <= 0.0 {
                    continue;
                }
                let capacity = self.hosts[h].effective_mips(self.reserved_util);
                let scale = (capacity / demand).min(1.0);
                for &v in &self.hosts[h].vms {
                    let n = self.vms[v].tasks.len().max(1) as f64;
                    let fair = self.vms[v].mips / n;
                    for &t in &self.vms[v].tasks {
                        let nominal = self.tasks[t].demand.mips.min(fair).max(1.0);
                        let expect_rate = nominal * scale / self.tasks[t].slowdown;
                        assert!(
                            self.rate_of(t).to_bits() == expect_rate.to_bits(),
                            "host {h} task {t} rate drift: cached {} recount {expect_rate}",
                            self.rate_of(t)
                        );
                    }
                }
            }
        }
        // Membership sets must contain only live states (spot-check via
        // contains on a few dead ids).
        for t in &self.tasks {
            if !t.is_active() {
                assert!(
                    !self.pending_set.contains(t.id)
                        && !self.running_set.contains(t.id)
                        && !self.held_set.contains(t.id),
                    "dead task {} still indexed",
                    t.id
                );
            }
        }
        // Load accounting + availability index (maintained only in indexed
        // mode).  Loads must match a from-scratch recount **bitwise** —
        // the caches are defined as the reference fold, not an
        // approximation of it.
        if !self.reference_scans {
            for v in 0..self.vms.len() {
                let expect = self.compute_vm_load(v);
                assert!(
                    self.vm_load[v] == expect,
                    "vm {v} load drift: cached {:?} recount {expect:?}",
                    self.vm_load[v]
                );
            }
            for h in 0..self.hosts.len() {
                let mut agg = ResLoad::default();
                let mut ntasks = 0usize;
                for &v in &self.hosts[h].vms {
                    let l = self.compute_vm_load(v);
                    agg.mips += l.mips;
                    agg.ram_gb += l.ram_gb;
                    agg.disk_gb += l.disk_gb;
                    agg.bw_kbps += l.bw_kbps;
                    ntasks += self.vms[v].tasks.len();
                }
                assert!(
                    self.host_load[h] == agg,
                    "host {h} load drift: cached {:?} recount {agg:?}",
                    self.host_load[h]
                );
                assert_eq!(self.host_tasks[h], ntasks, "host {h} task-counter drift");
            }
            // The availability index is exact whenever `now` last moved
            // through `advance` (which syncs) — tests that poke `now`
            // directly must not call this.
            let avail: Vec<VmId> =
                (0..self.vms.len()).filter(|&v| self.vm_available(v)).collect();
            assert_eq!(self.avail_set.sorted(), avail, "availability set drift");
            if !self.avail_dirty {
                assert_eq!(self.avail_sorted, avail, "availability cache drift");
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::sim::types::{TaskDemand, TaskState};
    use crate::util::ptest;

    fn world() -> World {
        World::new(&SimConfig::test_defaults())
    }

    fn add_task(w: &mut World, job: JobId, length: f64, mips: f64) -> TaskId {
        let id = w.n_tasks();
        w.add_task(Task {
            id,
            job,
            length_mi: length,
            demand: TaskDemand { mips, ram_gb: 0.1, disk_gb: 1.0, bw_kbps: 0.1 },
            state: TaskState::Pending,
            vm: None,
            last_vm: None,
            remaining_mi: length,
            submit_t: 0.0,
            first_start_t: None,
            restart_time: 0.0,
            restarts: 0,
            slowdown: 1.0,
            speculative_of: None,
            mitigated: false,
        })
    }

    #[test]
    fn fleet_construction_matches_config() {
        let cfg = SimConfig::test_defaults();
        let w = World::new(&cfg);
        assert_eq!(w.hosts.len(), cfg.total_pms());
        assert_eq!(w.vms.len(), cfg.total_vms());
        // every VM belongs to its host's list exactly once
        for v in &w.vms {
            assert!(w.hosts[v.host].vms.contains(&v.id));
        }
    }

    #[test]
    fn uncontended_task_runs_at_demand_rate() {
        let mut w = world();
        let t = add_task(&mut w, 0, 1000.0, 100.0);
        w.start_task(t, 0, 1.0);
        let rate = w.task_rate(t);
        assert!((rate - 100.0).abs() < 1e-9, "rate {rate}");
        let done = w.advance(10.0);
        assert_eq!(done, vec![t]);
    }

    #[test]
    fn slowdown_divides_rate() {
        let mut w = world();
        let t = add_task(&mut w, 0, 1000.0, 100.0);
        w.start_task(t, 0, 4.0);
        assert!((w.task_rate(t) - 25.0).abs() < 1e-9);
    }

    #[test]
    fn vm_fair_share_caps_rate() {
        let mut w = world();
        let vm_mips = w.vms[0].mips;
        let t1 = add_task(&mut w, 0, 1e6, 1e9);
        let t2 = add_task(&mut w, 0, 1e6, 1e9);
        w.start_task(t1, 0, 1.0);
        w.start_task(t2, 0, 1.0);
        let r1 = w.task_rate(t1);
        assert!((r1 - vm_mips / 2.0).abs() < 1e-6, "r1 {r1} vm {vm_mips}");
    }

    #[test]
    fn host_contention_scales_down() {
        let mut w = world();
        let host = 0;
        // Saturate every VM on host 0 with one huge-demand task.
        let vms: Vec<_> = w.hosts[host].vms.clone();
        let mut tasks = Vec::new();
        for &v in &vms {
            let t = add_task(&mut w, 0, 1e9, 1e9);
            w.start_task(t, v, 1.0);
            tasks.push(t);
        }
        // Also background load to force capacity below demand.
        w.set_background_load(host, 0.5);
        let total_rate: f64 = tasks.iter().map(|&t| w.task_rate(t)).sum();
        let cap = w.hosts[host].effective_mips(0.0);
        assert!(total_rate <= cap * 1.001, "total {total_rate} cap {cap}");
        assert!(w.host_cpu_util(host) >= 0.99);
    }

    #[test]
    fn advance_is_exact_piecewise() {
        let mut w = world();
        let t = add_task(&mut w, 0, 1000.0, 100.0);
        w.start_task(t, 0, 1.0);
        w.advance(3.0);
        assert!((w.task(t).remaining_mi - 700.0).abs() < 1e-9);
        assert!((w.task(t).progress() - 0.3).abs() < 1e-9);
        let eta = w.next_finish_time().unwrap();
        assert!((eta - 10.0).abs() < 1e-9);
    }

    #[test]
    fn down_host_contributes_no_rate() {
        let mut w = world();
        let t = add_task(&mut w, 0, 1000.0, 100.0);
        w.start_task(t, 0, 1.0);
        let h = w.vms[0].host;
        // `set_host_down` self-marks the host dirty — no manual
        // `mark_rates_dirty` needed.
        w.set_host_down(h, 1e9);
        assert_eq!(w.task_rate(t), 0.0);
        assert!(w.next_finish_time().is_none());
        w.assert_consistent();
    }

    #[test]
    fn availability_index_tracks_downtime_and_readiness() {
        let mut w = world();
        let n = w.vms.len();
        assert_eq!(w.available_vms().len(), n, "all VMs available at t=0");

        // Host goes down: its VMs leave the candidate list immediately.
        let h = w.vms[0].host;
        let on_host = w.hosts[h].vms.len();
        w.set_host_down(h, 40.0);
        assert_eq!(w.available_vms().len(), n - on_host);
        assert!(!w.vm_available(0));
        w.assert_consistent();

        // A VM elsewhere becomes unready.
        let other = *w.hosts[h + 1].vms.first().unwrap();
        w.set_vm_ready_at(other, 25.0);
        assert_eq!(w.available_vms().len(), n - on_host - 1);
        w.assert_consistent();

        // Advancing past the wake times re-admits, in ascending id order.
        w.advance(30.0);
        assert!(w.vm_available(other));
        assert_eq!(w.available_vms().len(), n - on_host);
        w.advance(45.0);
        let avail = w.available_vms().into_owned();
        assert_eq!(avail.len(), n);
        assert!(avail.windows(2).all(|p| p[0] < p[1]), "ascending order");
        w.assert_consistent();
    }

    #[test]
    fn overlapping_host_faults_keep_latest_recovery() {
        let mut w = world();
        let h = w.vms[0].host;
        // Second fault extends the outage; the first wake entry is stale.
        w.set_host_down(h, 20.0);
        w.set_host_down(h, 60.0);
        w.advance(25.0);
        assert!(!w.vm_available(0), "stale wake must not re-admit");
        w.assert_consistent();
        // And a shortened outage re-admits at the earlier time.
        w.set_host_down(h, 30.0);
        w.advance(31.0);
        assert!(w.vm_available(0));
        w.assert_consistent();
    }

    #[test]
    fn load_aggregates_match_reference_arithmetic() {
        let mut w = world();
        let mut r = world();
        r.reference_scans = true;
        for (i, vm) in [(0usize, 0usize), (1, 0), (2, 1), (3, 4)] {
            let len = 1000.0 + 7.0 * i as f64;
            let mips = 90.0 + 13.0 * i as f64;
            let a = add_task(&mut w, 0, len, mips);
            let b = add_task(&mut r, 0, len, mips);
            assert_eq!(a, b);
            w.start_task(a, vm, 1.0);
            r.start_task(b, vm, 1.0);
        }
        for h in 0..w.hosts.len() {
            assert_eq!(w.host_cpu_util(h), r.host_cpu_util(h), "cpu host {h}");
            assert_eq!(w.host_ram_util(h), r.host_ram_util(h), "ram host {h}");
            assert_eq!(w.host_disk_util(h), r.host_disk_util(h), "disk host {h}");
            assert_eq!(w.host_bw_util(h), r.host_bw_util(h), "bw host {h}");
            assert_eq!(w.host_task_count(h), r.host_task_count(h), "count host {h}");
        }
        // Detach one and re-check: subtotals are recomputed, not drifted.
        w.complete_task(1);
        r.complete_task(1);
        for h in 0..w.hosts.len() {
            assert_eq!(w.host_cpu_util(h), r.host_cpu_util(h), "cpu after detach {h}");
            assert_eq!(w.host_ram_util(h), r.host_ram_util(h), "ram after detach {h}");
        }
        w.assert_consistent();
    }

    #[test]
    fn reset_task_restores_work_and_counts_restart() {
        let mut w = world();
        let t = add_task(&mut w, 0, 1000.0, 100.0);
        w.start_task(t, 0, 1.0);
        w.advance(5.0);
        w.reset_task(t, 30.0);
        assert_eq!(w.task(t).state, TaskState::Pending);
        assert_eq!(w.task(t).remaining_mi, 1000.0);
        assert_eq!(w.task(t).restarts, 1);
        assert_eq!(w.task(t).restart_time, 30.0);
        assert!(w.vms[0].tasks.is_empty());
        w.assert_consistent();
    }

    #[test]
    fn complete_and_kill_detach_from_vm() {
        let mut w = world();
        let t1 = add_task(&mut w, 0, 1000.0, 100.0);
        let t2 = add_task(&mut w, 0, 1000.0, 100.0);
        w.start_task(t1, 0, 1.0);
        w.start_task(t2, 0, 1.0);
        w.advance(1.0);
        w.complete_task(t1);
        w.kill_task(t2);
        assert!(matches!(w.task(t1).state, TaskState::Completed { .. }));
        assert_eq!(w.task(t2).state, TaskState::Killed);
        assert!(w.vms[0].tasks.is_empty());
        assert_eq!(w.completed_log, vec![t1]);
        w.assert_consistent();
    }

    #[test]
    fn best_mitigation_vm_prefers_low_straggler_ema() {
        let mut w = world();
        for h in 0..w.hosts.len() {
            w.hosts[h].straggler_ema = 0.9;
        }
        let target_host = 3;
        w.hosts[target_host].straggler_ema = 0.0;
        let vm = w.best_mitigation_vm(None).unwrap();
        assert_eq!(w.vms[vm].host, target_host);
        // excluding that host picks another one
        let vm2 = w.best_mitigation_vm(Some(target_host)).unwrap();
        assert_ne!(w.vms[vm2].host, target_host);
    }

    #[test]
    fn straggler_ema_updates() {
        let mut w = world();
        w.note_straggler(0, true);
        assert!((w.hosts[0].straggler_ema - 0.2).abs() < 1e-12);
        w.note_straggler(0, false);
        assert!((w.hosts[0].straggler_ema - 0.16).abs() < 1e-12);
    }

    // ------------------------------------------------- index registry

    #[test]
    fn sets_track_lifecycle() {
        let mut w = world();
        let t1 = add_task(&mut w, 0, 1000.0, 100.0);
        let t2 = add_task(&mut w, 0, 1000.0, 100.0);
        assert_eq!(w.pending(), vec![t1, t2]);
        assert!(w.running().is_empty());
        assert_eq!(w.active_task_count(), 2);
        assert_eq!(w.job_active_count(0), 2);

        w.start_task(t1, 0, 1.0);
        assert_eq!(w.pending(), vec![t2]);
        assert_eq!(w.running(), vec![t1]);

        assert!(w.hold_task(t2, 50.0));
        assert_eq!(w.held(), vec![t2]);
        assert!(w.pending().is_empty());
        assert_eq!(w.release_expired_holds(), 0);
        w.advance(50.0);
        assert_eq!(w.release_expired_holds(), 1);
        assert_eq!(w.pending(), vec![t2]);

        w.complete_task(t1);
        assert!(w.running().is_empty());
        assert_eq!(w.job_active_count(0), 1);
        w.kill_task(t2);
        assert_eq!(w.active_task_count(), 0);
        assert_eq!(w.job_active_count(0), 0);
        w.assert_consistent();
    }

    #[test]
    fn active_job_set_follows_finish_job() {
        let mut w = world();
        let t = add_task(&mut w, 0, 1000.0, 100.0);
        w.add_job(Job {
            id: 0,
            tasks: vec![t],
            submit_t: 0.0,
            deadline_driven: false,
            sla_deadline: 1e9,
            sla_weight: 1.0,
            state: JobState::Active,
            true_alpha: 2.0,
            true_beta: 1.0,
        });
        assert!(w.has_active_jobs());
        assert_eq!(w.active_jobs(), vec![0]);
        w.start_task(t, 0, 1.0);
        w.advance(10.0);
        w.complete_task(t);
        w.finish_job(0);
        assert!(!w.has_active_jobs());
        assert_eq!(w.active_job_count(), 0);
        assert!(matches!(w.job(0).state, JobState::Done { .. }));
        w.assert_consistent();
    }

    #[test]
    fn clone_map_tracks_single_live_clone() {
        let mut w = world();
        let orig = add_task(&mut w, 0, 1000.0, 100.0);
        w.start_task(orig, 0, 4.0);
        let clone_id = w.n_tasks();
        w.add_task(Task {
            id: clone_id,
            job: 0,
            length_mi: 1000.0,
            demand: w.task(orig).demand,
            state: TaskState::Pending,
            vm: None,
            last_vm: None,
            remaining_mi: 1000.0,
            submit_t: 0.0,
            first_start_t: None,
            restart_time: 0.0,
            restarts: 0,
            slowdown: 1.0,
            speculative_of: Some(orig),
            mitigated: true,
        });
        assert_eq!(w.clone_of(orig), Some(clone_id));
        assert_eq!(w.live_clone_count(), 1);
        w.kill_task(clone_id);
        assert_eq!(w.clone_of(orig), None);
        assert_eq!(w.live_clone_count(), 0);
        w.assert_consistent();
    }

    #[test]
    fn finish_heap_matches_scan_minimum() {
        let mut w = world();
        let mut r = world();
        // Mirror worlds: identical ops, one indexed, one reference.
        r.reference_scans = true;
        for (len, mips, vm, slow) in
            [(1000.0, 100.0, 0usize, 1.0), (4000.0, 200.0, 1, 2.0), (900.0, 50.0, 2, 1.0)]
        {
            let a = add_task(&mut w, 0, len, mips);
            let b = add_task(&mut r, 0, len, mips);
            assert_eq!(a, b);
            w.start_task(a, vm, slow);
            r.start_task(b, vm, slow);
        }
        let fast = w.next_finish_time();
        let slow = r.next_finish_time();
        assert_eq!(fast, slow, "heap vs scan minimum");
        // Advance both to the first finish and compare again.
        let te = fast.unwrap();
        assert_eq!(w.advance(te), r.advance(te));
        w.assert_consistent();
    }

    /// Satellite (§11): rate-consistency arm — an indexed world and a
    /// reference world driven through identical random op sequences must
    /// agree **bitwise** on every task rate and on `next_finish_time`
    /// after every op, while `assert_consistent` recounts the maintained
    /// rates (and the heap's live-entry coverage) against a from-scratch
    /// reference pass.
    #[test]
    fn prop_rates_bitwise_match_reference_under_random_ops() {
        ptest::check("world-rate-consistency", 20, |rng| {
            let mut w = world();
            let mut r = world();
            r.reference_scans = true;
            let n_jobs = 2 + rng.below(3);
            for j in 0..n_jobs {
                let q = 1 + rng.below(5);
                let mut tasks = Vec::new();
                for _ in 0..q {
                    let len = rng.range(500.0, 5000.0);
                    let mips = rng.range(80.0, 400.0);
                    let a = add_task(&mut w, j, len, mips);
                    let b = add_task(&mut r, j, len, mips);
                    assert_eq!(a, b);
                    tasks.push(a);
                }
                for world in [&mut w, &mut r] {
                    world.add_job(Job {
                        id: j,
                        tasks: tasks.clone(),
                        submit_t: 0.0,
                        deadline_driven: false,
                        sla_deadline: 1e9,
                        sla_weight: 1.0,
                        state: JobState::Active,
                        true_alpha: 2.0,
                        true_beta: 1.0,
                    });
                }
            }
            for _ in 0..120 {
                match rng.below(8) {
                    0 => {
                        let p = w.pending();
                        if let Some(&t) = p.first() {
                            let vm = rng.below(w.vms.len());
                            if w.vm_available(vm) {
                                let slow = rng.range(1.0, 6.0);
                                w.start_task(t, vm, slow);
                                r.start_task(t, vm, slow);
                            }
                        }
                    }
                    1 => {
                        let run = w.running();
                        if !run.is_empty() {
                            let t = run[rng.below(run.len())];
                            w.complete_task(t);
                            r.complete_task(t);
                        }
                    }
                    2 => {
                        let run = w.running();
                        if !run.is_empty() {
                            let t = run[rng.below(run.len())];
                            w.kill_task(t);
                            r.kill_task(t);
                        }
                    }
                    3 => {
                        let run = w.running();
                        if !run.is_empty() {
                            let t = run[rng.below(run.len())];
                            w.reset_task(t, 30.0);
                            r.reset_task(t, 30.0);
                        }
                    }
                    4 => {
                        let to = w.now + rng.range(0.1, 60.0);
                        let dw = w.advance(to);
                        let dr = r.advance(to);
                        if dw != dr {
                            return Err(format!("advance divergence: {dw:?} vs {dr:?}"));
                        }
                        for t in dw {
                            w.complete_task(t);
                            r.complete_task(t);
                        }
                    }
                    5 => {
                        let h = rng.below(w.hosts.len());
                        let until = w.now + rng.range(1.0, 80.0);
                        w.set_host_down(h, until);
                        r.set_host_down(h, until);
                    }
                    6 => {
                        let h = rng.below(w.hosts.len());
                        let load = rng.range(0.0, 0.6);
                        w.set_background_load(h, load);
                        r.set_background_load(h, load);
                    }
                    _ => {
                        let v = rng.below(w.vms.len());
                        let at = w.now + rng.range(1.0, 50.0);
                        w.set_vm_ready_at(v, at);
                        r.set_vm_ready_at(v, at);
                    }
                }
                // Bitwise rate agreement for every task ever created.
                for t in 0..w.n_tasks() {
                    let a = w.task_rate(t);
                    let b = r.task_rate(t);
                    if a.to_bits() != b.to_bits() {
                        return Err(format!("task {t} rate drift: indexed {a} reference {b}"));
                    }
                }
                let (fa, fb) = (w.next_finish_time(), r.next_finish_time());
                if fa.map(f64::to_bits) != fb.map(f64::to_bits) {
                    return Err(format!("next_finish_time drift: {fa:?} vs {fb:?}"));
                }
                w.assert_consistent();
            }
            Ok(())
        });
    }

    /// Satellite: property-style invariant check — pending/running/held and
    /// per-job counters stay consistent with task states under random
    /// place/hold/kill/complete/reset/speculate sequences.
    #[test]
    fn prop_indexes_consistent_under_random_ops() {
        ptest::check("world-index-consistency", 30, |rng| {
            let mut w = world();
            // Trace-consistency arm: record every transition and check,
            // after each random op, that the event stream recounts to the
            // same live sets as the world's indexes.
            #[cfg(feature = "sim-trace")]
            w.set_trace(TraceSink::mem());
            // 2–4 jobs with 1–5 tasks each.
            let n_jobs = 2 + rng.below(3);
            for j in 0..n_jobs {
                let q = 1 + rng.below(5);
                let mut tasks = Vec::new();
                for _ in 0..q {
                    tasks.push(add_task(&mut w, j, rng.range(500.0, 5000.0), rng.range(80.0, 400.0)));
                }
                w.add_job(Job {
                    id: j,
                    tasks,
                    submit_t: 0.0,
                    deadline_driven: rng.chance(0.5),
                    sla_deadline: 1e9,
                    sla_weight: 1.0,
                    state: JobState::Active,
                    true_alpha: 2.0,
                    true_beta: 1.0,
                });
            }
            for _ in 0..150 {
                match rng.below(11) {
                    0 => {
                        // place a pending task
                        let p = w.pending();
                        if let Some(&t) = p.first() {
                            let vm = rng.below(w.vms.len());
                            if w.vm_available(vm) {
                                w.start_task(t, vm, rng.range(1.0, 6.0));
                            }
                        }
                    }
                    1 => {
                        let r = w.running();
                        if !r.is_empty() {
                            w.complete_task(r[rng.below(r.len())]);
                        }
                    }
                    2 => {
                        let r = w.running();
                        if !r.is_empty() {
                            w.kill_task(r[rng.below(r.len())]);
                        }
                    }
                    3 => {
                        let r = w.running();
                        if !r.is_empty() {
                            w.reset_task(r[rng.below(r.len())], 30.0);
                        }
                    }
                    4 => {
                        let p = w.pending();
                        if !p.is_empty() {
                            w.hold_task(p[rng.below(p.len())], w.now + rng.range(1.0, 100.0));
                        }
                    }
                    5 => {
                        let dt = rng.range(0.1, 60.0);
                        let to = w.now + dt;
                        for t in w.advance(to) {
                            w.complete_task(t);
                        }
                        w.release_expired_holds();
                    }
                    6 => {
                        // speculate a running original via the mitigation path
                        let r = w.running();
                        let orig = r
                            .into_iter()
                            .find(|&t| w.task(t).speculative_of.is_none() && w.clone_of(t).is_none());
                        if let Some(t) = orig {
                            let _ = crate::mitigation::speculate(&mut w, t, rng.range(1.0, 3.0));
                        }
                    }
                    7 => {
                        // close out jobs whose tasks are all inactive
                        let jobs = w.active_jobs();
                        for j in jobs {
                            if w.job_active_count(j) == 0 {
                                w.finish_job(j);
                            }
                        }
                    }
                    8 => {
                        // host fault (possibly overlapping a live outage)
                        let h = rng.below(w.hosts.len());
                        let until = w.now + rng.range(1.0, 80.0);
                        w.set_host_down(h, until);
                    }
                    9 => {
                        // VM readiness delay (VmCreation-style fault)
                        let v = rng.below(w.vms.len());
                        let at = w.now + rng.range(1.0, 50.0);
                        w.set_vm_ready_at(v, at);
                    }
                    _ => {
                        // background-load shift (rate-change event)
                        let h = rng.below(w.hosts.len());
                        w.set_background_load(h, rng.range(0.0, 0.6));
                    }
                }
                w.assert_consistent();
                #[cfg(feature = "sim-trace")]
                {
                    let rc = crate::sim::trace::recount(w.trace_events());
                    if rc.pending != w.pending()
                        || rc.running != w.running()
                        || rc.held != w.held()
                        || rc.active_jobs != w.active_jobs()
                    {
                        return Err(format!(
                            "event recount disagrees with live sets: {rc:?} vs \
                             pending={:?} running={:?} held={:?} jobs={:?}",
                            w.pending(),
                            w.running(),
                            w.held(),
                            w.active_jobs()
                        ));
                    }
                }
            }
            // Accessors agree with a forced reference re-scan — including
            // the load aggregates and the availability index, bitwise.
            let pend = w.pending();
            let run = w.running();
            let held = w.held();
            let jobs = w.active_jobs();
            let avail = w.available_vms().into_owned();
            let utils: Vec<(f64, f64, f64, f64, usize)> = (0..w.hosts.len())
                .map(|h| {
                    (
                        w.host_cpu_util(h),
                        w.host_ram_util(h),
                        w.host_disk_util(h),
                        w.host_bw_util(h),
                        w.host_task_count(h),
                    )
                })
                .collect();
            w.reference_scans = true;
            if pend != w.pending() || run != w.running() || held != w.held() || jobs != w.active_jobs()
            {
                return Err("indexed accessors disagree with reference scans".into());
            }
            if avail != w.available_vms().into_owned() {
                return Err("availability index disagrees with reference scan".into());
            }
            for (h, &(cpu, ram, disk, bw, n)) in utils.iter().enumerate() {
                let refer =
                    (w.host_cpu_util(h), w.host_ram_util(h), w.host_disk_util(h), w.host_bw_util(h));
                if (cpu, ram, disk, bw) != refer {
                    return Err(format!(
                        "host {h} aggregates disagree: indexed {:?} reference {refer:?}",
                        (cpu, ram, disk, bw)
                    ));
                }
                if n != w.host_task_count(h) {
                    return Err(format!("host {h} task count disagrees"));
                }
            }
            Ok(())
        });
    }
}
