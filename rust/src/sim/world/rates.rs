//! Lazy execution-rate maintenance (DESIGN.md §11): dirty-host sets,
//! epoch-stamped per-task rates, the generation-stamped finish-time heap,
//! and exact piecewise-linear time advancement.
//!
//! Owns the invariant that **every maintained rate equals a from-scratch
//! reference recompute, bitwise**: each task's rate is
//! `nominal * scale / slowdown` where `nominal = min(demand, fair_share)
//! .max(1.0)` and `scale = (capacity / demand).min(1.0)` over host-local
//! state only, so re-rating just the dirty hosts writes the same bits a
//! full pass would.  `reference_scans` mode keeps the seed's global
//! recompute alive as the parity oracle.

use crate::sim::types::*;
use crate::sim::world::ids::{Arena, IdSet};
use crate::sim::world::World;
use std::cmp::{Ordering, Reverse};
use std::collections::BinaryHeap;

/// Total-ordered f64 wrapper for heap keys (etas are never NaN).
#[derive(Clone, Copy, PartialEq)]
pub(super) struct EtaKey(pub(super) f64);

impl Eq for EtaKey {}

impl PartialOrd for EtaKey {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for EtaKey {
    fn cmp(&self, other: &Self) -> Ordering {
        self.0.partial_cmp(&other.0).unwrap_or(Ordering::Equal)
    }
}

/// Per-task execution rates + the staleness machinery that keeps them
/// lazily correct.
pub(super) struct RateIndex {
    /// Per-task execution rate in MI/s (slowdown already applied);
    /// recomputed lazily from the dirty-host set.  Entries are valid only
    /// when their stamp matches the current epoch — this avoids the
    /// O(total) zero-fill the seed engine paid on every recompute.  In
    /// indexed mode the epoch never moves (host-local recompute stamps
    /// the current epoch and invalidates by writing stamp 0, which is
    /// below the initial epoch); only the reference full pass bumps it.
    pub(super) rate: Arena<TaskId, f64>,
    pub(super) stamp: Arena<TaskId, u64>,
    pub(super) epoch: u64,
    /// Hosts whose resident rates are stale (DESIGN.md §11): every
    /// rate-affecting mutation marks only the host(s) it touched, and
    /// `recompute_dirty_hosts` re-runs the exact reference arithmetic for
    /// just those hosts.  `all_dirty` is the coarse fallback
    /// (`mark_rates_dirty`, and the only flavor reference mode uses — it
    /// keeps the seed's global recompute alive as the oracle).
    pub(super) dirty_hosts: IdSet<HostId>,
    pub(super) all_dirty: bool,
    /// Hosts that were down at their last recompute: their residents
    /// carry no rate.  Matching the seed semantics — where recovery alone
    /// never triggers a recompute — they are re-rated only when the
    /// *next* recompute (caused by some other dirty event) observes them
    /// up.
    pub(super) down_stale: IdSet<HostId>,
    /// Min-heap of (projected absolute finish time, task, generation)
    /// over running tasks with positive rate.  Never cleared wholesale:
    /// each host-local recompute pushes fresh entries (with a bumped
    /// per-task generation stamp) for the tasks it re-rated, and
    /// consumers pop-and-discard entries whose stamp no longer matches
    /// `heap_gen` — the same lazy-invalidation discipline as the §9
    /// availability wake heap.  Etas are time-invariant under constant
    /// rates, and are always re-derived from live task state at the peek
    /// site.
    pub(super) finish_heap: BinaryHeap<Reverse<(EtaKey, TaskId, u64)>>,
    /// Current finish-heap generation per task; bumped on every re-rate
    /// and on unplacement, so older heap entries become stale.
    pub(super) heap_gen: Arena<TaskId, u64>,
}

impl RateIndex {
    pub(super) fn new() -> RateIndex {
        RateIndex {
            rate: Arena::new(),
            stamp: Arena::new(),
            epoch: 1,
            dirty_hosts: IdSet::new(),
            all_dirty: true,
            down_stale: IdSet::new(),
            finish_heap: BinaryHeap::new(),
            heap_gen: Arena::new(),
        }
    }
}

impl World {
    /// Whether any rate is stale (the old single `rates_dirty` bit).
    /// `down_stale` alone does **not** count: host recovery never
    /// triggers a recompute (seed semantics) — recovered hosts are swept
    /// up by the next recompute some other dirty event causes.
    fn rates_dirty(&self) -> bool {
        self.rates.all_dirty || !self.rates.dirty_hosts.is_empty()
    }

    /// Mark one host's resident rates stale.  Reference mode collapses to
    /// the seed's single dirty bit (global recompute).
    pub(super) fn mark_host_rates_dirty(&mut self, host: HostId) {
        if self.reference_scans {
            self.rates.all_dirty = true;
        } else {
            self.rates.dirty_hosts.insert(host);
        }
    }

    /// Recompute stale rates before a rate-dependent query.  Reference
    /// mode runs the seed-faithful global pass; indexed mode re-rates
    /// only the dirty hosts.
    fn recompute_if_dirty(&mut self) {
        if !self.rates_dirty() {
            return;
        }
        if self.reference_scans {
            self.recompute_rates_reference();
        } else {
            self.recompute_dirty_hosts();
        }
    }

    /// Seed-faithful global recompute (reference mode only): O(total)
    /// zero-fill plus a full-fleet pass in host/VM/task order, bumping
    /// the validity epoch so every stamp from earlier passes goes stale.
    ///
    /// Model: each task's fair demand on its VM is
    /// `min(demand.mips, vm.mips / n_tasks)`; a host whose aggregate VM
    /// demand exceeds its effective capacity (after background + reserved
    /// load) scales every resident task proportionally — this is the
    /// resource-contention mechanism (Eq. 9's "overloaded" condition).
    // Index loops are deliberate: they split borrows across `hosts`/
    // `vms`/`tasks`/`rates` fields, which iterator chains cannot.
    #[allow(clippy::needless_range_loop)]
    fn recompute_rates_reference(&mut self) {
        self.rates.epoch += 1;
        let epoch = self.rates.epoch;
        // Seed-faithful O(total) zero-fill; the indexed path instead
        // invalidates by stamp so dead tasks cost nothing.
        for r in self.rates.rate.iter_mut() {
            *r = 0.0;
        }
        // Reference mode answers `next_finish_time` by full scan, so it
        // must not pay (or rely on) heap upkeep.
        self.rates.finish_heap.clear();
        for hi in 0..self.hosts.len() {
            let h = HostId::new(hi);
            let host = &self.hosts[h];
            if !host.is_up(self.now) {
                continue;
            }
            let demand: f64 = host.vms.iter().map(|&v| self.vm_demand(v)).sum();
            if demand <= 0.0 {
                continue;
            }
            let capacity = host.effective_mips(self.reserved_util);
            let scale = (capacity / demand).min(1.0);
            for vi in 0..self.hosts[h].vms.len() {
                let v = self.hosts[h].vms[vi];
                let vm = &self.vms[v];
                let n = vm.tasks.len().max(1) as f64;
                let fair = vm.mips / n;
                for ti in 0..self.vms[v].tasks.len() {
                    let t = self.vms[v].tasks[ti];
                    let nominal = self.registry.tasks[t].demand.mips.min(fair).max(1.0);
                    let rate = nominal * scale / self.registry.tasks[t].slowdown;
                    self.rates.rate[t] = rate;
                    self.rates.stamp[t] = epoch;
                }
            }
        }
        self.rates.all_dirty = false;
        self.rates.dirty_hosts.clear();
    }

    /// Host-local recompute (DESIGN.md §11): re-run the reference
    /// arithmetic for exactly the dirty hosts — plus recovered
    /// `down_stale` hosts — and push fresh generation-stamped finish-heap
    /// entries for their running residents.  Rates on untouched hosts
    /// (and their live heap entries) are left as the previous pass wrote
    /// them, which is bit-identical to what a full pass would write: the
    /// rate arithmetic reads only host-local state, and the §9
    /// `host_load` demand aggregate is maintained bitwise equal to the
    /// reference per-VM fold.
    fn recompute_dirty_hosts(&mut self) {
        if self.rates.all_dirty {
            for hi in 0..self.hosts.len() {
                self.recompute_host(HostId::new(hi));
            }
        } else {
            // Dirty hosts plus recovered hosts whose residents still
            // carry stale zero rates; ascending id — the full-pass host
            // order.
            let mut targets = self.rates.dirty_hosts.to_vec();
            for i in 0..self.rates.down_stale.len() {
                let h = self.rates.down_stale.as_slice()[i];
                if self.hosts[h].is_up(self.now) && !self.rates.dirty_hosts.contains(h) {
                    targets.push(h);
                }
            }
            targets.sort_unstable();
            for h in targets {
                self.recompute_host(h);
            }
        }
        self.rates.all_dirty = false;
        self.rates.dirty_hosts.clear();
        self.compact_finish_heap();
    }

    /// Re-rate one host with the exact reference arithmetic (same
    /// expressions, same `host.vms`/`vm.tasks` fold order).  Down hosts
    /// contribute no rate: their residents' stamps are invalidated and
    /// the host parks in `down_stale` until a later recompute sees it up.
    #[allow(clippy::needless_range_loop)]
    fn recompute_host(&mut self, h: HostId) {
        if !self.hosts[h].is_up(self.now) {
            for vi in 0..self.hosts[h].vms.len() {
                let v = self.hosts[h].vms[vi];
                for ti in 0..self.vms[v].tasks.len() {
                    let t = self.vms[v].tasks[ti];
                    self.rates.stamp[t] = 0;
                    self.rates.heap_gen[t] += 1;
                }
            }
            self.rates.down_stale.insert(h);
            return;
        }
        self.rates.down_stale.remove(h);
        // §9 aggregate: bitwise equal to the reference per-VM demand fold.
        let demand = self.load.host[h].mips;
        if demand <= 0.0 {
            // No residents (every resident demands >= 1 MIPS), so there is
            // nothing to re-rate or invalidate.
            return;
        }
        let capacity = self.hosts[h].effective_mips(self.reserved_util);
        let scale = (capacity / demand).min(1.0);
        let now = self.now;
        let epoch = self.rates.epoch;
        for vi in 0..self.hosts[h].vms.len() {
            let v = self.hosts[h].vms[vi];
            let n = self.vms[v].tasks.len().max(1) as f64;
            let fair = self.vms[v].mips / n;
            for ti in 0..self.vms[v].tasks.len() {
                let t = self.vms[v].tasks[ti];
                let nominal = self.registry.tasks[t].demand.mips.min(fair).max(1.0);
                let rate = nominal * scale / self.registry.tasks[t].slowdown;
                self.rates.rate[t] = rate;
                self.rates.stamp[t] = epoch;
                if rate > 0.0 && self.registry.tasks[t].is_running() {
                    self.rates.heap_gen[t] += 1;
                    let gen = self.rates.heap_gen[t];
                    let eta = now + self.registry.tasks[t].remaining_mi / rate;
                    self.rates.finish_heap.push(Reverse((EtaKey(eta), t, gen)));
                }
            }
        }
    }

    /// Deterministic size bound on the lazily-invalidated finish heap:
    /// when stale entries outnumber live ones ~4:1, rebuild from the live
    /// set (stored etas kept verbatim).  Triggered by sim state only —
    /// never wall clock — so replays and the parity contract are
    /// unaffected.
    fn compact_finish_heap(&mut self) {
        if self.rates.finish_heap.len() <= 64 + 4 * self.registry.running.len() {
            return;
        }
        let live: Vec<_> = std::mem::take(&mut self.rates.finish_heap)
            .into_vec()
            .into_iter()
            .filter(|&Reverse((_, t, gen))| {
                self.rates.heap_gen[t] == gen
                    && self.registry.tasks[t].is_running()
                    && self.rate_of(t) > 0.0
            })
            .collect();
        self.rates.finish_heap = BinaryHeap::from(live);
    }

    /// Rate of a task under the current epoch (0 if not computed = idle,
    /// dead, or on a down host).
    pub(super) fn rate_of(&self, task: TaskId) -> f64 {
        match self.rates.stamp.get(task) {
            Some(&s) if s == self.rates.epoch => self.rates.rate[task],
            _ => 0.0,
        }
    }

    /// Force a full rate recomputation on next use.  The typed mutators
    /// self-mark the hosts they touch, so this coarse fallback is only
    /// for callers that mutated rate inputs outside the typed surface.
    pub fn mark_rates_dirty(&mut self) {
        self.rates.all_dirty = true;
    }

    /// Current rate of a task (MI/s).
    pub fn task_rate(&mut self, task: TaskId) -> f64 {
        self.recompute_if_dirty();
        self.rate_of(task)
    }

    /// Earliest projected completion time among running tasks.
    ///
    /// Indexed mode peeks the lazy finish-time heap (O(1) when rates are
    /// clean); the returned eta is always re-derived from the task's live
    /// remaining work so both modes share one arithmetic definition (and
    /// `advance` is guaranteed to make progress — a cached value could
    /// land an ulp short of the completion threshold and stall the loop).
    ///
    /// Caveat: the heap orders by etas cached at recompute time.  Etas
    /// are time-invariant under clean rates in exact arithmetic, but if
    /// time advanced since the rebuild (fault events that do not touch
    /// rates), two etas within a few ulps of each other could rank
    /// differently than a fresh scan.  Candidate etas derive from
    /// independent continuous draws (Pareto slowdowns, normal task
    /// sizes), so such near-ties have effectively zero measure; the
    /// parity suite runs both modes across seeds/fault-rates to back this
    /// empirically.
    pub fn next_finish_time(&mut self) -> Option<f64> {
        self.recompute_if_dirty();
        if self.reference_scans {
            let now = self.now;
            let mut best: Option<f64> = None;
            for ti in 0..self.registry.tasks.len() {
                let t = TaskId::new(ti);
                if self.registry.tasks[t].is_running() {
                    let rate = self.rate_of(t);
                    if rate > 0.0 {
                        let eta = now + self.registry.tasks[t].remaining_mi / rate;
                        best = Some(match best {
                            Some(b) => b.min(eta),
                            None => eta,
                        });
                    }
                }
            }
            return best;
        }
        // Lazy invalidation: discard entries whose generation stamp is
        // stale (task re-rated, unplaced, or its host went down since the
        // push); the first live entry is the minimum.
        while let Some(&Reverse((_, t, gen))) = self.rates.finish_heap.peek() {
            if self.rates.heap_gen[t] == gen && self.registry.tasks[t].is_running() {
                let rate = self.rate_of(t);
                if rate > 0.0 {
                    return Some(self.now + self.registry.tasks[t].remaining_mi / rate);
                }
            }
            self.rates.finish_heap.pop();
        }
        None
    }

    /// Advance simulated time to `to`, consuming work on all running
    /// tasks.  Returns tasks whose remaining work reached zero, in
    /// ascending id order.
    #[allow(clippy::needless_range_loop)]
    pub fn advance(&mut self, to: f64) -> Vec<TaskId> {
        debug_assert!(to >= self.now - 1e-9, "time must be monotone");
        self.recompute_if_dirty();
        let dt = (to - self.now).max(0.0);
        self.now = to;
        // Re-admit VMs whose ready/recovery time has now passed.  `now`
        // only moves here, so the availability index is exact at every
        // query point.
        self.sync_availability();
        if dt == 0.0 {
            return Vec::new();
        }
        let mut done = Vec::new();
        if self.reference_scans {
            for ti in 0..self.registry.tasks.len() {
                let t = TaskId::new(ti);
                if self.registry.tasks[t].is_running() {
                    let rate = self.rate_of(t);
                    if rate > 0.0 {
                        self.registry.tasks[t].remaining_mi -= rate * dt;
                        if self.registry.tasks[t].remaining_mi <= 1e-6 {
                            done.push(t);
                        }
                    }
                }
            }
        } else {
            // The running set iterates in ascending id order (it is kept
            // sorted), and per-task updates are independent, so `done`
            // comes out ascending with no post-sort — same order the
            // reference scan produces.
            for i in 0..self.registry.running.len() {
                let t = self.registry.running.as_slice()[i];
                let rate = self.rate_of(t);
                if rate > 0.0 {
                    self.registry.tasks[t].remaining_mi -= rate * dt;
                    if self.registry.tasks[t].remaining_mi <= 1e-6 {
                        done.push(t);
                    }
                }
            }
        }
        done
    }

    /// Layer check (§11): live finish-heap entries must cover exactly the
    /// running-with-rate set, down hosts must be parked in `down_stale`
    /// with unrated residents, and every maintained rate must equal a
    /// from-scratch reference recompute **bitwise**.  Skipped while rates
    /// are dirty (they are lazily recomputed at the next rate query) and
    /// in reference mode (no maintained state to check).
    pub(super) fn assert_rates_consistent(&self) {
        if self.rates_dirty() || self.reference_scans {
            return;
        }
        // Live heap entries (generation stamp current) must cover
        // exactly the running-with-rate set, with no duplicates.
        let mut heap_ids: Vec<TaskId> = self
            .rates
            .finish_heap
            .iter()
            .filter(|Reverse((_, t, gen))| self.rates.heap_gen[*t] == *gen)
            .map(|Reverse((_, t, _))| *t)
            .collect();
        heap_ids.sort_unstable();
        assert!(
            heap_ids.windows(2).all(|p| p[0] != p[1]),
            "duplicate live finish-heap entries"
        );
        let expect: Vec<TaskId> =
            self.registry.running.iter().filter(|&t| self.rate_of(t) > 0.0).collect();
        assert_eq!(heap_ids, expect, "finish-heap membership drift");
        // Tentpole invariant (§11): every maintained rate must equal a
        // from-scratch reference recompute, bitwise.  Hosts parked in
        // `down_stale` (down, or recovered but not yet re-rated) instead
        // carry no rate at all.
        for hi in 0..self.hosts.len() {
            let h = HostId::new(hi);
            if !self.hosts[h].is_up(self.now) {
                assert!(
                    self.rates.down_stale.contains(h),
                    "down host {h} missing from down_stale"
                );
            }
            if self.rates.down_stale.contains(h) {
                for &v in &self.hosts[h].vms {
                    for &t in &self.vms[v].tasks {
                        assert_eq!(
                            self.rate_of(t),
                            0.0,
                            "stale-down host {h}: task {t} still rated"
                        );
                    }
                }
                continue;
            }
            let demand: f64 =
                self.hosts[h].vms.iter().map(|&v| self.compute_vm_load(v).mips).sum();
            if demand <= 0.0 {
                continue;
            }
            let capacity = self.hosts[h].effective_mips(self.reserved_util);
            let scale = (capacity / demand).min(1.0);
            for &v in &self.hosts[h].vms {
                let n = self.vms[v].tasks.len().max(1) as f64;
                let fair = self.vms[v].mips / n;
                for &t in &self.vms[v].tasks {
                    let nominal = self.registry.tasks[t].demand.mips.min(fair).max(1.0);
                    let expect_rate = nominal * scale / self.registry.tasks[t].slowdown;
                    assert!(
                        self.rate_of(t).to_bits() == expect_rate.to_bits(),
                        "host {h} task {t} rate drift: cached {} recount {expect_rate}",
                        self.rate_of(t)
                    );
                }
            }
        }
    }
}
