//! Fleet topology: physical-host / VM construction and host- and
//! VM-level state transitions (faults, background load, readiness).
//!
//! Owns the invariant that **all host up/down and VM-readiness changes go
//! through world methods** (`set_host_down`, `set_vm_ready_at`,
//! `set_background_load`) — never by writing `down_until` /
//! `background_load` / `ready_at` directly — so the availability index
//! (`load.rs`) and the dirty-host rate set (`rates.rs`) can never miss a
//! transition.

use crate::config::SimConfig;
use crate::sim::types::*;
use crate::sim::world::ids::Arena;
use crate::sim::world::World;

/// Build the PM fleet + VMs from config (Table 3 PM types).
pub(super) fn build_fleet(cfg: &SimConfig) -> (Arena<HostId, Host>, Arena<VmId, Vm>) {
    let mut hosts: Arena<HostId, Host> = Arena::new();
    let mut vms: Arena<VmId, Vm> = Arena::new();
    for (type_idx, (&count, ty)) in cfg.pm_counts.iter().zip(&cfg.pm_types).enumerate() {
        for _ in 0..count {
            let hid = HostId::new(hosts.len());
            let mut host = Host {
                id: hid,
                type_idx,
                mips_total: ty.mips_per_core * ty.cores as f64,
                ram_gb: ty.ram_gb,
                disk_gb: ty.disk_gb,
                bw_kbps: ty.bw_kbps,
                power_idle_w: ty.power_idle_w,
                power_peak_w: ty.power_peak_w,
                cost_per_interval: ty.cost_per_interval,
                vms: Vec::new(),
                down_until: None,
                straggler_ema: 0.0,
                background_load: 0.0,
            };
            for _ in 0..ty.vms_per_pm {
                let vid = VmId::new(vms.len());
                host.vms.push(vid);
                vms.push(Vm {
                    id: vid,
                    host: hid,
                    mips: host.mips_total / ty.vms_per_pm as f64,
                    ram_gb: ty.ram_gb / ty.vms_per_pm as f64,
                    tasks: Vec::new(),
                    ready_at: 0.0,
                });
            }
            hosts.push(host);
        }
    }
    (hosts, vms)
}

impl World {
    /// Whether a VM can currently accept work.
    pub fn vm_available(&self, vm: VmId) -> bool {
        let v = &self.vms[vm];
        v.ready_at <= self.now && self.hosts[v.host].is_up(self.now)
    }

    /// Absolute time at which a VM (re)enters the available set: the later
    /// of its readiness and its host's recovery.  `<= now` iff available.
    pub(super) fn vm_wake_time(&self, vm: VmId) -> f64 {
        let v = &self.vms[vm];
        v.ready_at.max(self.hosts[v.host].down_until.unwrap_or(f64::NEG_INFINITY))
    }

    /// Take a host down until `until`, updating the availability index.
    /// All host up/down transitions must go through here (not by writing
    /// `down_until` directly) so the index cannot drift.
    pub fn set_host_down(&mut self, host: HostId, until: f64) {
        self.hosts[host].down_until = Some(until);
        self.mark_host_rates_dirty(host);
        if !self.reference_scans {
            // Index loop splits the borrow of `hosts[host].vms` from the
            // `&mut self` availability refresh, as in `recompute_host`.
            for vi in 0..self.hosts[host].vms.len() {
                let vm = self.hosts[host].vms[vi];
                self.refresh_vm_availability(vm);
            }
        }
    }

    /// Set a host's background load (the per-interval trace refresh),
    /// dirtying its rates only when the value actually changed (bitwise).
    /// All background-load writes must go through here so the dirty-host
    /// set cannot miss a rate change.
    pub fn set_background_load(&mut self, host: HostId, load: f64) {
        if self.hosts[host].background_load.to_bits() != load.to_bits() {
            self.hosts[host].background_load = load;
            self.mark_host_rates_dirty(host);
        }
    }

    /// Set a VM's readiness time, updating the availability index.
    pub fn set_vm_ready_at(&mut self, vm: VmId, ready_at: f64) {
        self.vms[vm].ready_at = ready_at;
        if !self.reference_scans {
            self.refresh_vm_availability(vm);
        }
    }

    /// Update the per-host straggler moving average (Alg. 1's node-choice
    /// signal): called when a task is classified at completion.
    pub fn note_straggler(&mut self, host: HostId, was_straggler: bool) {
        let h = &mut self.hosts[host];
        let x = if was_straggler { 1.0 } else { 0.0 };
        h.straggler_ema = 0.8 * h.straggler_ema + 0.2 * x;
    }

    /// Pick the up-VM on the host with the lowest straggler moving average
    /// (the paper's mitigation target choice), breaking ties toward
    /// unloaded hosts so mitigation does not itself create contention.
    /// Candidates come from the availability index (ascending id — the
    /// order the pre-index `0..vms.len()` filter produced), and the
    /// per-host key reads the O(1) aggregates.
    pub fn best_mitigation_vm(&self, exclude_host: Option<HostId>) -> Option<VmId> {
        let mut best: Option<((i64, i64, usize), VmId)> = None;
        for &v in self.available_vms().iter() {
            let host = self.vms[v].host;
            if Some(host) == exclude_host {
                continue;
            }
            // Quantized straggler EMA first (the paper's signal), then
            // host CPU utilization, then VM queue depth.
            let key = (
                (self.hosts[host].straggler_ema * 10.0) as i64,
                (self.host_cpu_util(host) * 20.0) as i64,
                self.vms[v].tasks.len(),
            );
            if best.map(|(b, _)| key < b).unwrap_or(true) {
                best = Some((key, v));
            }
        }
        best.map(|(_, v)| v)
    }

    /// Fleet-wide maxima used for feature normalization.
    pub fn fleet_max(&self) -> (f64, f64, f64, f64) {
        let mut m = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
        for h in &self.hosts {
            m.0 = m.0.max(h.mips_total);
            m.1 = m.1.max(h.ram_gb);
            m.2 = m.2.max(h.disk_gb);
            m.3 = m.3.max(h.bw_kbps);
        }
        m
    }
}
