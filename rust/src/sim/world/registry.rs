//! The index-maintained entity registry (DESIGN.md §3): task/job arenas,
//! state-membership sets, per-job active counters, the speculative-clone
//! map, and every task lifecycle transition.
//!
//! Owns the invariant that **membership indexes never drift from task
//! state**: the arenas are private and every state change funnels through
//! `set_task_state`, which keeps `pending`/`running`/`held`, the per-job
//! active-task counters, and the clone map in lockstep.  Queries
//! (`pending()`, `running()`, `held()`, `active_jobs()`) borrow the
//! always-sorted sets directly — O(1), zero-alloc — while
//! `reference_scans` mode re-derives each answer with the seed's O(total)
//! full scan as the parity oracle.

use crate::sim::trace::{Event, LifeState};
use crate::sim::types::*;
use crate::sim::world::ids::{Arena, IdSet};
use crate::sim::world::World;
use std::borrow::Cow;
use std::collections::HashMap;

/// Entity arenas + state-membership indexes.
pub(super) struct Registry {
    pub(super) tasks: Arena<TaskId, Task>,
    pub(super) jobs: Arena<JobId, Job>,
    pub(super) pending: IdSet<TaskId>,
    pub(super) running: IdSet<TaskId>,
    pub(super) held: IdSet<TaskId>,
    pub(super) active_jobs: IdSet<JobId>,
    /// Tasks in an active state (pending/running/held) per job.
    pub(super) job_active_tasks: Arena<JobId, usize>,
    /// Active speculative copies, fleet-wide.
    pub(super) live_clones: usize,
    /// original task → its (single) live speculative clone.
    pub(super) active_clone: HashMap<TaskId, TaskId>,
}

impl Registry {
    pub(super) fn new() -> Registry {
        Registry {
            tasks: Arena::new(),
            jobs: Arena::new(),
            pending: IdSet::new(),
            running: IdSet::new(),
            held: IdSet::new(),
            active_jobs: IdSet::new(),
            job_active_tasks: Arena::new(),
            live_clones: 0,
            active_clone: HashMap::new(),
        }
    }
}

impl World {
    /// Register a new task (id must be `n_tasks()`); indexes it by state.
    pub fn add_task(&mut self, t: Task) -> TaskId {
        let id = TaskId::new(self.registry.tasks.len());
        debug_assert_eq!(t.id, id, "task ids are dense");
        if t.job.raw() >= self.registry.job_active_tasks.len() {
            self.registry.job_active_tasks.resize(t.job.raw() + 1, 0);
        }
        let job = t.job;
        let active = t.is_active();
        let spec_of = t.speculative_of;
        let now = self.now;
        let submit_t = t.submit_t;
        let life = match t.state {
            TaskState::Pending => LifeState::Pending,
            TaskState::Running => LifeState::Running,
            TaskState::Held { .. } => LifeState::Held,
            TaskState::Completed { .. } | TaskState::Killed => LifeState::Done,
        };
        self.trace.record(|| Event::TaskAdmit {
            t: now,
            task: id,
            job,
            submit_t,
            speculative_of: spec_of,
            state: life,
        });
        self.registry.tasks.push(t);
        // Per-task rate/heap bookkeeping stays dense with the arena, so
        // targeted invalidation never has to bounds-check or resize.
        self.rates.rate.push(0.0);
        self.rates.stamp.push(0);
        self.rates.heap_gen.push(0);
        if active {
            self.registry.job_active_tasks[job] += 1;
            if let Some(orig) = spec_of {
                debug_assert!(
                    !self.registry.active_clone.contains_key(&orig),
                    "task {orig} already has a live clone"
                );
                self.registry.live_clones += 1;
                self.registry.active_clone.insert(orig, id);
            }
        }
        self.index_enter_state(id);
        id
    }

    /// Register a new job (id must be `n_jobs()`).
    pub fn add_job(&mut self, j: Job) -> JobId {
        let id = JobId::new(self.registry.jobs.len());
        debug_assert_eq!(j.id, id, "job ids are dense");
        if id.raw() >= self.registry.job_active_tasks.len() {
            self.registry.job_active_tasks.resize(id.raw() + 1, 0);
        }
        let active = j.is_active();
        let now = self.now;
        self.trace.record(|| Event::JobAdmit {
            t: now,
            job: id,
            tasks: j.tasks.clone(),
            deadline_driven: j.deadline_driven,
            sla_weight: j.sla_weight,
        });
        self.registry.jobs.push(j);
        if active {
            self.registry.active_jobs.insert(id);
        }
        id
    }

    /// Mark a job done at the current time (all tasks completed).
    pub fn finish_job(&mut self, job: JobId) {
        if self.registry.jobs[job].is_active() {
            self.registry.jobs[job].state = JobState::Done { t: self.now };
            self.registry.active_jobs.remove(job);
            let now = self.now;
            self.trace.record(|| Event::JobDone { t: now, job });
        }
    }

    /// Record a mitigation action against a task (prediction scoring).
    pub fn mark_mitigated(&mut self, task: TaskId) {
        self.registry.tasks[task].mitigated = true;
    }

    /// Set the ground-truth Pareto parameters sampled at submission.
    pub fn set_job_ground_truth(&mut self, job: JobId, alpha: f64, beta: f64) {
        self.registry.jobs[job].true_alpha = alpha;
        self.registry.jobs[job].true_beta = beta;
    }

    /// Set a job's absolute SLA deadline.
    pub fn set_job_sla_deadline(&mut self, job: JobId, deadline: f64) {
        self.registry.jobs[job].sla_deadline = deadline;
        let now = self.now;
        self.trace.record(|| Event::JobSla { t: now, job, deadline });
    }

    fn index_enter_state(&mut self, id: TaskId) {
        match self.registry.tasks[id].state {
            TaskState::Pending => {
                self.registry.pending.insert(id);
            }
            TaskState::Running => {
                self.registry.running.insert(id);
            }
            TaskState::Held { .. } => {
                self.registry.held.insert(id);
            }
            _ => {}
        }
    }

    fn index_leave_state(&mut self, id: TaskId) {
        match self.registry.tasks[id].state {
            TaskState::Pending => {
                self.registry.pending.remove(id);
            }
            TaskState::Running => {
                self.registry.running.remove(id);
            }
            TaskState::Held { .. } => {
                self.registry.held.remove(id);
            }
            _ => {}
        }
    }

    /// The single choke point for task state changes: keeps the membership
    /// sets, per-job counters and clone map consistent.
    fn set_task_state(&mut self, id: TaskId, state: TaskState) {
        let was_active = self.registry.tasks[id].is_active();
        self.index_leave_state(id);
        self.registry.tasks[id].state = state;
        self.index_enter_state(id);
        let is_active = self.registry.tasks[id].is_active();
        if was_active == is_active {
            return;
        }
        let job = self.registry.tasks[id].job;
        if is_active {
            self.registry.job_active_tasks[job] += 1;
        } else {
            self.registry.job_active_tasks[job] -= 1;
        }
        if let Some(orig) = self.registry.tasks[id].speculative_of {
            if is_active {
                debug_assert!(!self.registry.active_clone.contains_key(&orig));
                self.registry.live_clones += 1;
                self.registry.active_clone.insert(orig, id);
            } else {
                self.registry.live_clones -= 1;
                if self.registry.active_clone.get(&orig) == Some(&id) {
                    self.registry.active_clone.remove(&orig);
                }
            }
        }
    }

    // ------------------------------------------------------------ queries

    /// Read a task.
    pub fn task(&self, id: TaskId) -> &Task {
        &self.registry.tasks[id]
    }

    /// Read a job.
    pub fn job(&self, id: JobId) -> &Job {
        &self.registry.jobs[id]
    }

    /// Total tasks ever created (dense id space).
    pub fn n_tasks(&self) -> usize {
        self.registry.tasks.len()
    }

    /// Total jobs ever created (dense id space).
    pub fn n_jobs(&self) -> usize {
        self.registry.jobs.len()
    }

    /// Pending tasks, ascending id (the placement queue).  Borrows the
    /// membership set — callers that mutate the world mid-walk own a
    /// snapshot first via `.to_vec()`/`.into_owned()`.
    pub fn pending(&self) -> Cow<'_, [TaskId]> {
        if self.reference_scans {
            return Cow::Owned(
                self.registry
                    .tasks
                    .iter()
                    .filter(|t| t.state == TaskState::Pending)
                    .map(|t| t.id)
                    .collect(),
            );
        }
        Cow::Borrowed(self.registry.pending.as_slice())
    }

    /// Running tasks, ascending id.
    pub fn running(&self) -> Cow<'_, [TaskId]> {
        if self.reference_scans {
            return Cow::Owned(
                self.registry.tasks.iter().filter(|t| t.is_running()).map(|t| t.id).collect(),
            );
        }
        Cow::Borrowed(self.registry.running.as_slice())
    }

    /// Held (Wrangler-delayed) tasks, ascending id.
    pub fn held(&self) -> Cow<'_, [TaskId]> {
        if self.reference_scans {
            return Cow::Owned(
                self.registry
                    .tasks
                    .iter()
                    .filter(|t| matches!(t.state, TaskState::Held { .. }))
                    .map(|t| t.id)
                    .collect(),
            );
        }
        Cow::Borrowed(self.registry.held.as_slice())
    }

    /// Jobs still active, ascending id.
    pub fn active_jobs(&self) -> Cow<'_, [JobId]> {
        if self.reference_scans {
            return Cow::Owned(
                self.registry.jobs.iter().filter(|j| j.is_active()).map(|j| j.id).collect(),
            );
        }
        Cow::Borrowed(self.registry.active_jobs.as_slice())
    }

    /// Whether any job is still active (the drain-loop check).
    pub fn has_active_jobs(&self) -> bool {
        if self.reference_scans {
            return self.registry.jobs.iter().any(|j| j.is_active());
        }
        !self.registry.active_jobs.is_empty()
    }

    /// Number of active jobs.
    pub fn active_job_count(&self) -> usize {
        if self.reference_scans {
            return self.registry.jobs.iter().filter(|j| j.is_active()).count();
        }
        self.registry.active_jobs.len()
    }

    /// Number of tasks in an active state (pending/running/held).
    pub fn active_task_count(&self) -> usize {
        if self.reference_scans {
            return self.registry.tasks.iter().filter(|t| t.is_active()).count();
        }
        self.registry.pending.len() + self.registry.running.len() + self.registry.held.len()
    }

    /// Active tasks of one job (counter-backed fast path for emptiness).
    /// Counts every task carrying the job id — **including live
    /// speculative clones** — unlike `active_tasks`, which walks the
    /// job's original task list only.
    pub fn job_active_count(&self, job: JobId) -> usize {
        self.registry.job_active_tasks.get(job).copied().unwrap_or(0)
    }

    /// Live speculative copies fleet-wide (the baselines' clone budgets).
    pub fn live_clone_count(&self) -> usize {
        if self.reference_scans {
            return self
                .registry
                .tasks
                .iter()
                .filter(|t| t.speculative_of.is_some() && t.is_active())
                .count();
        }
        self.registry.live_clones
    }

    /// The live speculative clone of `task`, if any.
    pub fn clone_of(&self, task: TaskId) -> Option<TaskId> {
        if self.reference_scans {
            // Clones are appended after their original; scan backwards.
            return self
                .registry
                .tasks
                .iter()
                .rev()
                .find(|t| t.speculative_of == Some(task) && t.is_active())
                .map(|t| t.id);
        }
        self.registry.active_clone.get(&task).copied()
    }

    /// All tasks, including dead ones.  O(total) — **test/debug escape
    /// hatch only** (conservation recounts, invariant checks); hot-path
    /// code must use the set accessors above, which this deliberately
    /// bypasses.
    pub fn debug_tasks(&self) -> &[Task] {
        self.registry.tasks.as_slice()
    }

    /// All jobs.  O(total) — **test/debug escape hatch only**; see
    /// `debug_tasks`.
    pub fn debug_jobs(&self) -> &[Job] {
        self.registry.jobs.as_slice()
    }

    /// Active (pending/running/held) tasks of a job — **originals only**
    /// (speculative clones are not in `Job::tasks`); see
    /// `job_active_count` for the clone-inclusive counter.  Borrowing
    /// iterator; collect if you need ownership across mutation.
    pub fn active_tasks(&self, job: JobId) -> impl Iterator<Item = TaskId> + '_ {
        self.registry.jobs[job]
            .tasks
            .iter()
            .copied()
            .filter(move |&t| self.registry.tasks[t].is_active())
    }

    /// Completed tasks of a job (non-speculative originals count once).
    pub fn completed_tasks(&self, job: JobId) -> usize {
        self.registry.jobs[job]
            .tasks
            .iter()
            .filter(|&&t| matches!(self.registry.tasks[t].state, TaskState::Completed { .. }))
            .count()
    }

    // --------------------------------------------------------- placement

    /// Start (or restart) a task on a VM.  `slowdown` is the Pareto
    /// duration multiplier sampled by the caller from the job's
    /// ground-truth distribution.
    pub fn start_task(&mut self, task: TaskId, vm: VmId, slowdown: f64) {
        debug_assert!(self.registry.tasks[task].vm.is_none(), "task already placed");
        self.set_task_state(task, TaskState::Running);
        let now = self.now;
        let t = &mut self.registry.tasks[task];
        t.vm = Some(vm);
        t.last_vm = Some(vm);
        t.slowdown = slowdown.max(1e-3);
        if t.first_start_t.is_none() {
            t.first_start_t = Some(now);
        }
        self.vms[vm].tasks.push(task);
        self.mark_host_rates_dirty(self.vms[vm].host);
        if !self.reference_scans {
            self.load.host_tasks[self.vms[vm].host] += 1;
            self.refresh_vm_load(vm);
        }
        let sd = self.registry.tasks[task].slowdown;
        self.trace.record(|| Event::TaskStart { t: now, task, vm, slowdown: sd });
    }

    /// Remove a task from its VM (completion, kill, restart).
    pub fn unplace_task(&mut self, task: TaskId) {
        if let Some(vm) = self.registry.tasks[task].vm.take() {
            self.vms[vm].tasks.retain(|&t| t != task);
            self.mark_host_rates_dirty(self.vms[vm].host);
            // The detached task is no longer rated: the host-local
            // recompute will not revisit it, so invalidate its stamp here
            // and retire any finish-heap entry it still has.
            self.rates.stamp[task] = 0;
            self.rates.heap_gen[task] += 1;
            if !self.reference_scans {
                self.load.host_tasks[self.vms[vm].host] -= 1;
                self.refresh_vm_load(vm);
            }
        }
    }

    /// Mark a task completed now and detach it.
    pub fn complete_task(&mut self, task: TaskId) {
        self.unplace_task(task);
        self.set_task_state(task, TaskState::Completed { t: self.now });
        self.registry.tasks[task].remaining_mi = 0.0;
        self.completed_log.push(task);
        let now = self.now;
        self.trace.record(|| Event::TaskComplete { t: now, task });
    }

    /// Complete a task whose result arrived via its speculative clone: the
    /// logical task is done but this execution did not itself finish (it
    /// keeps its residual work and is not appended to the completion log).
    pub fn complete_superseded(&mut self, task: TaskId) {
        self.unplace_task(task);
        self.set_task_state(task, TaskState::Completed { t: self.now });
        let now = self.now;
        self.trace.record(|| Event::TaskSuperseded { t: now, task });
    }

    /// Kill a task (lost race / superseded) and detach it.
    pub fn kill_task(&mut self, task: TaskId) {
        self.unplace_task(task);
        self.set_task_state(task, TaskState::Killed);
        let now = self.now;
        self.trace.record(|| Event::TaskKill { t: now, task });
    }

    /// Reset a task to pending with full work (restart after fault/rerun);
    /// accumulates restart bookkeeping.
    pub fn reset_task(&mut self, task: TaskId, restart_penalty_s: f64) {
        self.unplace_task(task);
        self.set_task_state(task, TaskState::Pending);
        let t = &mut self.registry.tasks[task];
        t.remaining_mi = t.length_mi;
        t.restarts += 1;
        t.restart_time += restart_penalty_s;
        let now = self.now;
        self.trace.record(|| Event::TaskReset { t: now, task, penalty_s: restart_penalty_s });
    }

    /// Put a pending task on hold until `until` (Wrangler-style delaying).
    pub fn hold_task(&mut self, task: TaskId, until: f64) -> bool {
        if self.registry.tasks[task].state == TaskState::Pending {
            self.set_task_state(task, TaskState::Held { until });
            let now = self.now;
            self.trace.record(|| Event::TaskHold { t: now, task, until });
            true
        } else {
            false
        }
    }

    /// Release held tasks whose hold expired (back to Pending).
    pub fn release_expired_holds(&mut self) -> usize {
        let now = self.now;
        // Both modes share one expiry predicate; only the candidate id
        // source differs (full scan vs held set), so the parity contract
        // cannot drift if the epsilon or the Held match ever changes.
        let is_expired = |t: &Task| match t.state {
            TaskState::Held { until } => now + 1e-9 >= until,
            _ => false,
        };
        let expired: Vec<TaskId> = if self.reference_scans {
            self.registry
                .tasks
                .enumerate()
                .filter(|(_, t)| is_expired(t))
                .map(|(id, _)| id)
                .collect()
        } else {
            self.registry
                .held
                .iter()
                .filter(|&t| is_expired(&self.registry.tasks[t]))
                .collect()
        };
        for &t in &expired {
            self.set_task_state(t, TaskState::Pending);
            self.trace.record(|| Event::TaskRelease { t: now, task: t });
        }
        expired.len()
    }

    /// Layer check (§3): recount every membership set, per-job counter,
    /// and the clone map from a full task scan, and verify placement
    /// residency (a running task sits on exactly one VM; anything else is
    /// unplaced and unrated).
    pub(super) fn assert_registry_consistent(&self) {
        let mut pend = Vec::new();
        let mut run = Vec::new();
        let mut held = Vec::new();
        let mut job_active = vec![0usize; self.registry.job_active_tasks.len()];
        let mut clones = 0usize;
        let mut clone_map: HashMap<TaskId, TaskId> = HashMap::new();
        for t in self.registry.tasks.iter() {
            match t.state {
                TaskState::Pending => pend.push(t.id),
                TaskState::Running => run.push(t.id),
                TaskState::Held { .. } => held.push(t.id),
                _ => {}
            }
            if t.is_active() {
                if t.job.raw() >= job_active.len() {
                    job_active.resize(t.job.raw() + 1, 0);
                }
                job_active[t.job.raw()] += 1;
                if let Some(orig) = t.speculative_of {
                    clones += 1;
                    let prev = clone_map.insert(orig, t.id);
                    assert!(prev.is_none(), "two live clones of task {orig}");
                }
            }
        }
        assert_eq!(self.registry.pending.as_slice(), pend, "pending set drift");
        assert_eq!(self.registry.running.as_slice(), run, "running set drift");
        assert_eq!(self.registry.held.as_slice(), held, "held set drift");
        assert_eq!(self.registry.live_clones, clones, "live-clone counter drift");
        assert_eq!(self.registry.active_clone.len(), clone_map.len(), "clone map size drift");
        for (orig, clone) in &clone_map {
            assert_eq!(
                self.registry.active_clone.get(orig),
                Some(clone),
                "clone map drift for task {orig}"
            );
        }
        for (j, &n) in job_active.iter().enumerate() {
            assert_eq!(
                self.registry.job_active_tasks.get(JobId::new(j)).copied().unwrap_or(0),
                n,
                "active-task counter drift for job {j}"
            );
        }
        let active_jobs: Vec<JobId> =
            self.registry.jobs.iter().filter(|j| j.is_active()).map(|j| j.id).collect();
        assert_eq!(self.registry.active_jobs.as_slice(), active_jobs, "active-job set drift");
        for t in self.registry.tasks.iter() {
            match t.state {
                TaskState::Running => {
                    let vm = t.vm.expect("running task must be placed");
                    assert_eq!(
                        self.vms[vm].tasks.iter().filter(|&&x| x == t.id).count(),
                        1,
                        "task {} not resident exactly once on vm {vm}",
                        t.id
                    );
                }
                _ => {
                    assert!(t.vm.is_none(), "non-running task {} still placed", t.id);
                    assert_eq!(self.rate_of(t.id), 0.0, "unplaced task {} still rated", t.id);
                }
            }
        }
        // Membership sets must contain only live states.
        for t in self.registry.tasks.iter() {
            if !t.is_active() {
                assert!(
                    !self.registry.pending.contains(t.id)
                        && !self.registry.running.contains(t.id)
                        && !self.registry.held.contains(t.id),
                    "dead task {} still indexed",
                    t.id
                );
            }
        }
    }
}
