//! Typed entity identifiers and the containers indexed by them.
//!
//! Every simulator entity — host, VM, task, job — is addressed by a
//! `#[repr(transparent)]` newtype over its arena index.  The raw `usize`
//! is only reachable through `new`/`raw`, so a `TaskId` can never be used
//! to index the host arena (or vice versa) without a compile error.  This
//! module is the **only** place where entity ids and raw integers
//! interconvert; CI greps for `usize` casts on id types elsewhere.
//!
//! Two containers build on the newtypes:
//!
//! * [`Arena<I, T>`] — a grow-only `Vec<T>` indexable *only* by its id
//!   type `I` (`world.tasks[tid]`, `world.hosts[hid]`).
//! * [`IdSet<I>`] — an always-sorted set of ids.  Because the backing
//!   vector is kept sorted at all times, membership queries are
//!   `O(log n)` and — crucially for the zero-alloc query surface — the
//!   set can hand out its contents as a borrowed `&[I]` with no per-call
//!   allocation or sort.

use std::marker::PhantomData;
use std::ops::{Index, IndexMut};

/// Common surface of the four entity-id newtypes: conversion to/from the
/// raw arena index.  Kept as a trait so generic containers ([`Arena`],
/// [`IdSet`]) and serialization helpers can be written once.
pub trait EntityId: Copy + Ord + std::hash::Hash + std::fmt::Debug {
    /// Wrap a raw arena index.
    fn new(raw: usize) -> Self;
    /// Unwrap to the raw arena index.
    fn raw(self) -> usize;
}

macro_rules! entity_id {
    ($(#[$doc:meta])* $name:ident) => {
        $(#[$doc])*
        #[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
        #[repr(transparent)]
        pub struct $name(usize);

        impl $name {
            /// Wrap a raw arena index.
            #[inline(always)]
            pub const fn new(raw: usize) -> Self {
                Self(raw)
            }
            /// Unwrap to the raw arena index.
            #[inline(always)]
            pub const fn raw(self) -> usize {
                self.0
            }
        }

        impl EntityId for $name {
            #[inline(always)]
            fn new(raw: usize) -> Self {
                Self(raw)
            }
            #[inline(always)]
            fn raw(self) -> usize {
                self.0
            }
        }

        // Ids print as the bare number (no `TaskId(..)` wrapper): panic
        // messages, trace labels, and `{:?}` dumps stay byte-identical
        // with the former `usize` aliases.
        impl std::fmt::Debug for $name {
            fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
                write!(f, "{}", self.0)
            }
        }

        impl std::fmt::Display for $name {
            fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
                write!(f, "{}", self.0)
            }
        }
    };
}

entity_id!(
    /// Index of a physical machine in `World::hosts`.
    HostId
);
entity_id!(
    /// Index of a virtual machine in `World::vms`.
    VmId
);
entity_id!(
    /// Index of a task (cloudlet) in the task arena.
    TaskId
);
entity_id!(
    /// Index of a bag-of-tasks job in the job arena.
    JobId
);

/// Grow-only storage indexable only by its id type.
///
/// A thin wrapper over `Vec<T>` whose `Index`/`IndexMut` impls take `I`
/// rather than `usize`, so cross-entity indexing bugs (task id into the
/// host arena) are compile errors.  Iteration order is id order.
#[derive(Clone, Debug, Default)]
pub struct Arena<I: EntityId, T> {
    items: Vec<T>,
    _ids: PhantomData<I>,
}

impl<I: EntityId, T> Arena<I, T> {
    pub fn new() -> Self {
        Self { items: Vec::new(), _ids: PhantomData }
    }

    pub fn with_capacity(cap: usize) -> Self {
        Self { items: Vec::with_capacity(cap), _ids: PhantomData }
    }

    #[inline(always)]
    pub fn len(&self) -> usize {
        self.items.len()
    }

    #[inline(always)]
    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Append an item, returning the id it was stored under.
    #[inline]
    pub fn push(&mut self, item: T) -> I {
        let id = I::new(self.items.len());
        self.items.push(item);
        id
    }

    /// `None` when `id` is beyond the arena (used for counters that may
    /// lag entity admission, e.g. per-job active-task tallies).
    #[inline(always)]
    pub fn get(&self, id: I) -> Option<&T> {
        self.items.get(id.raw())
    }

    #[inline(always)]
    pub fn get_mut(&mut self, id: I) -> Option<&mut T> {
        self.items.get_mut(id.raw())
    }

    /// Grow (or shrink) to `len` entries, filling with clones of `fill`.
    pub fn resize(&mut self, len: usize, fill: T)
    where
        T: Clone,
    {
        self.items.resize(len, fill);
    }

    /// All valid ids, in order.
    pub fn ids(&self) -> impl DoubleEndedIterator<Item = I> + ExactSizeIterator + Clone {
        (0..self.items.len()).map(I::new)
    }

    pub fn iter(&self) -> std::slice::Iter<'_, T> {
        self.items.iter()
    }

    pub fn iter_mut(&mut self) -> std::slice::IterMut<'_, T> {
        self.items.iter_mut()
    }

    /// `(id, &item)` pairs in id order.
    pub fn enumerate(&self) -> impl Iterator<Item = (I, &T)> {
        self.items.iter().enumerate().map(|(i, t)| (I::new(i), t))
    }

    /// Raw slice view (id order).  For O(total) debug walks; typed access
    /// should index by id.
    pub fn as_slice(&self) -> &[T] {
        &self.items
    }
}

impl<I: EntityId, T> Index<I> for Arena<I, T> {
    type Output = T;
    #[inline(always)]
    fn index(&self, id: I) -> &T {
        &self.items[id.raw()]
    }
}

impl<I: EntityId, T> IndexMut<I> for Arena<I, T> {
    #[inline(always)]
    fn index_mut(&mut self, id: I) -> &mut T {
        &mut self.items[id.raw()]
    }
}

impl<'a, I: EntityId, T> IntoIterator for &'a Arena<I, T> {
    type Item = &'a T;
    type IntoIter = std::slice::Iter<'a, T>;
    fn into_iter(self) -> Self::IntoIter {
        self.items.iter()
    }
}

impl<'a, I: EntityId, T> IntoIterator for &'a mut Arena<I, T> {
    type Item = &'a mut T;
    type IntoIter = std::slice::IterMut<'a, T>;
    fn into_iter(self) -> Self::IntoIter {
        self.items.iter_mut()
    }
}

impl<I: EntityId, T> FromIterator<T> for Arena<I, T> {
    fn from_iter<It: IntoIterator<Item = T>>(iter: It) -> Self {
        Self { items: iter.into_iter().collect(), _ids: PhantomData }
    }
}

/// Always-sorted id set.
///
/// Membership mutation keeps the backing vector sorted (binary-search
/// insert/remove), so `as_slice()` is a zero-cost ordered view — the
/// query surface (`pending()`, `running()`, `available_vms()`, …)
/// borrows it directly instead of clone-and-sorting a dense set on every
/// call.  Sets track *active* entities, which stay small relative to the
/// arena totals, so the `O(n)` memmove on insert/remove is cheap; id
/// membership flips dwarf id lookups in no workload we model.
#[derive(Clone, Debug, Default)]
pub struct IdSet<I: EntityId> {
    sorted: Vec<I>,
}

impl<I: EntityId> IdSet<I> {
    pub fn new() -> Self {
        Self { sorted: Vec::new() }
    }

    /// Insert `id`; no-op when already present.
    #[inline]
    pub fn insert(&mut self, id: I) {
        if let Err(pos) = self.sorted.binary_search(&id) {
            self.sorted.insert(pos, id);
        }
    }

    /// Remove `id`; no-op when absent.
    #[inline]
    pub fn remove(&mut self, id: I) {
        if let Ok(pos) = self.sorted.binary_search(&id) {
            self.sorted.remove(pos);
        }
    }

    #[inline(always)]
    pub fn contains(&self, id: I) -> bool {
        self.sorted.binary_search(&id).is_ok()
    }

    #[inline(always)]
    pub fn len(&self) -> usize {
        self.sorted.len()
    }

    #[inline(always)]
    pub fn is_empty(&self) -> bool {
        self.sorted.is_empty()
    }

    pub fn clear(&mut self) {
        self.sorted.clear();
    }

    /// Members in ascending id order, borrowed — the zero-alloc view.
    #[inline(always)]
    pub fn as_slice(&self) -> &[I] {
        &self.sorted
    }

    pub fn iter(&self) -> impl DoubleEndedIterator<Item = I> + ExactSizeIterator + '_ {
        self.sorted.iter().copied()
    }

    /// Owned ascending copy — the explicit escape hatch for callers that
    /// mutate the world while walking the membership snapshot.
    pub fn to_vec(&self) -> Vec<I> {
        self.sorted.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ids_print_as_bare_numbers() {
        let t = TaskId::new(17);
        assert_eq!(format!("{t}"), "17");
        assert_eq!(format!("{t:?}"), "17");
        assert_eq!(t.raw(), 17);
        assert_eq!(format!("{:?}", HostId::new(3)), "3");
    }

    #[test]
    fn arena_typed_indexing_and_iteration() {
        let mut a: Arena<VmId, &str> = Arena::new();
        let v0 = a.push("a");
        let v1 = a.push("b");
        assert_eq!(v0, VmId::new(0));
        assert_eq!(a[v1], "b");
        a[v0] = "z";
        assert_eq!(a.get(VmId::new(5)), None);
        let ids: Vec<VmId> = a.ids().collect();
        assert_eq!(ids, vec![v0, v1]);
        let via_ref: Vec<&&str> = (&a).into_iter().collect();
        assert_eq!(via_ref, vec![&"z", &"b"]);
        assert_eq!(a.enumerate().map(|(i, _)| i).collect::<Vec<_>>(), ids);
    }

    #[test]
    fn idset_stays_sorted_and_dedups() {
        let mut s: IdSet<TaskId> = IdSet::new();
        for raw in [5usize, 1, 9, 1, 3, 9] {
            s.insert(TaskId::new(raw));
        }
        assert_eq!(s.len(), 4);
        let got: Vec<usize> = s.as_slice().iter().map(|t| t.raw()).collect();
        assert_eq!(got, vec![1, 3, 5, 9]);
        assert!(s.contains(TaskId::new(3)));
        s.remove(TaskId::new(3));
        s.remove(TaskId::new(100)); // absent: no-op
        assert!(!s.contains(TaskId::new(3)));
        assert_eq!(s.to_vec(), s.as_slice().to_vec());
        s.clear();
        assert!(s.is_empty());
    }
}
