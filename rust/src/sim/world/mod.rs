//! The mutable simulation world: entity storage, capacity/contention
//! math, task placement and exact piecewise-linear progress advancement
//! — decomposed into typed subsystems (DESIGN.md §13).
//!
//! `World` is a facade over four layers, each owning one family of
//! invariants (and the layer's slice of `assert_consistent`):
//!
//! * [`ids`] — `#[repr(transparent)]` entity-id newtypes
//!   (`HostId`/`VmId`/`TaskId`/`JobId`), typed arenas, and always-sorted
//!   id sets.  The only module where ids and raw `usize` interconvert.
//! * [`registry`] — task/job arenas, `pending`/`running`/`held`/
//!   `active_jobs` membership sets, per-job counters, the
//!   speculative-clone map, and all lifecycle transitions (§3).
//! * [`topology`] — host/VM fleet construction and fault-state
//!   transitions (`set_host_down`, `set_vm_ready_at`,
//!   `set_background_load`).
//! * [`load`] — per-VM/per-host `ResLoad` demand subtotals and the
//!   VM-availability index (§9).
//! * [`rates`] — dirty-host rate maintenance, the generation-stamped
//!   finish heap, and `advance` (§11).
//!
//! Queries (`pending()`, `running()`, `held()`, `active_jobs()`,
//! `available_vms()`) are **zero-alloc borrowed views** over the sorted
//! membership sets; `active_tasks(job)` is a borrowing iterator.  All
//! state transitions go through world methods so the indexes can never
//! drift from entity state.  `SimConfig::reference_scans` flips every
//! query back to the pre-index O(total)/O(fleet) full scans — the
//! golden-parity test and the `scale`/`placement`/`rates` benchmarks run
//! both modes and compare bitwise.

pub mod ids;
mod load;
mod rates;
mod registry;
mod topology;

#[cfg(test)]
mod tests;

use crate::config::SimConfig;
use crate::sim::trace::{Event, TraceSink};
use crate::sim::types::*;

use ids::Arena;
use load::LoadIndex;
use rates::RateIndex;
use registry::Registry;

/// Entity storage + derived execution rates (facade over the layer
/// subsystems; see the module docs for the layer map).
pub struct World {
    pub now: f64,
    pub hosts: Arena<HostId, Host>,
    pub vms: Arena<VmId, Vm>,
    /// Reserved-utilization knob (Fig. 6/8 sweep).
    pub reserved_util: f64,
    /// Latest raw M_H snapshot (set by the coordinator's feature extractor
    /// each interval; consumed by job-submission generative sampling).
    pub latest_m_h: Vec<f32>,
    /// Completed-task log for metrics: (task, completion_time).
    pub completed_log: Vec<TaskId>,
    /// Parity/debug mode: answer queries via the seed engine's O(total)
    /// full scans instead of the indexes.
    pub(crate) reference_scans: bool,
    /// Entity registry layer (§3): arenas + state membership indexes.
    pub(crate) registry: Registry,
    /// Load-accounting + availability layer (§9).
    pub(crate) load: LoadIndex,
    /// Rate-maintenance layer (§11).
    pub(crate) rates: RateIndex,
    /// Structured event sink (sim/trace.rs): every state transition
    /// records through it.  Off by default — one predicted branch per
    /// site; install with [`World::set_trace`].
    pub(crate) trace: TraceSink,
}

impl World {
    /// Build the PM fleet + VMs from config.
    pub fn new(cfg: &SimConfig) -> World {
        let (hosts, vms) = topology::build_fleet(cfg);
        let (n_hosts, n_vms) = (hosts.len(), vms.len());
        World {
            now: 0.0,
            hosts,
            vms,
            reserved_util: cfg.reserved_util,
            latest_m_h: Vec::new(),
            completed_log: Vec::new(),
            reference_scans: cfg.reference_scans,
            registry: Registry::new(),
            load: LoadIndex::new(n_hosts, n_vms),
            rates: RateIndex::new(),
            trace: TraceSink::default(),
        }
    }

    // -------------------------------------------------------- observability

    /// Install an event sink; subsequent state transitions are recorded.
    pub fn set_trace(&mut self, sink: TraceSink) {
        self.trace = sink;
    }

    /// Remove and return the sink (leaves tracing off).
    pub fn take_trace(&mut self) -> TraceSink {
        std::mem::take(&mut self.trace)
    }

    /// Events collected so far (in-memory sinks; empty otherwise).
    pub fn trace_events(&self) -> &[Event] {
        self.trace.events()
    }

    /// Record an event through the sink.  The closure runs only when
    /// tracing is enabled; it may capture any non-`World` state (the
    /// engine records decision events through this without borrowing the
    /// rest of the world).
    #[inline(always)]
    pub fn trace_record(&mut self, f: impl FnOnce() -> Event) {
        self.trace.record(f);
    }

    // ---------------------------------------------------------- invariants

    /// Cross-check every incremental index against a from-scratch O(total)
    /// recount, layer by layer (each layer's check lives next to the state
    /// it guards).  Panics (with a description) on any drift.  Test/debug
    /// only — this is intentionally the full scan the indexes replace.
    pub fn assert_consistent(&self) {
        // §3: membership sets, per-job counters, clone map, placement
        // residency.
        self.assert_registry_consistent();
        // §11: finish-heap coverage, down_stale parking, bitwise rate
        // recount (skipped while dirty / in reference mode).
        self.assert_rates_consistent();
        // §9: load caches bitwise, host task counters, availability set
        // (maintained only in indexed mode).
        if !self.reference_scans {
            self.assert_loads_consistent();
        }
    }
}
