//! World unit + property tests: rate arithmetic, lifecycle indexes,
//! availability, load aggregates, and the indexed-vs-reference parity
//! arms (mirror worlds driven through identical op sequences).

use super::*;
use crate::config::SimConfig;
use crate::sim::types::{TaskDemand, TaskState};
use crate::util::ptest;

fn world() -> World {
    World::new(&SimConfig::test_defaults())
}

fn vm(n: usize) -> VmId {
    VmId::new(n)
}

fn host(n: usize) -> HostId {
    HostId::new(n)
}

fn job(n: usize) -> JobId {
    JobId::new(n)
}

fn add_task(w: &mut World, job_n: usize, length: f64, mips: f64) -> TaskId {
    let id = TaskId::new(w.n_tasks());
    w.add_task(Task {
        id,
        job: JobId::new(job_n),
        length_mi: length,
        demand: TaskDemand { mips, ram_gb: 0.1, disk_gb: 1.0, bw_kbps: 0.1 },
        state: TaskState::Pending,
        vm: None,
        last_vm: None,
        remaining_mi: length,
        submit_t: 0.0,
        first_start_t: None,
        restart_time: 0.0,
        restarts: 0,
        slowdown: 1.0,
        speculative_of: None,
        mitigated: false,
    })
}

fn mk_job(n: usize, tasks: Vec<TaskId>, deadline_driven: bool) -> Job {
    Job {
        id: JobId::new(n),
        tasks,
        submit_t: 0.0,
        deadline_driven,
        sla_deadline: 1e9,
        sla_weight: 1.0,
        state: JobState::Active,
        true_alpha: 2.0,
        true_beta: 1.0,
    }
}

#[test]
fn fleet_construction_matches_config() {
    let cfg = SimConfig::test_defaults();
    let w = World::new(&cfg);
    assert_eq!(w.hosts.len(), cfg.total_pms());
    assert_eq!(w.vms.len(), cfg.total_vms());
    // every VM belongs to its host's list exactly once
    for v in &w.vms {
        assert!(w.hosts[v.host].vms.contains(&v.id));
    }
}

#[test]
fn uncontended_task_runs_at_demand_rate() {
    let mut w = world();
    let t = add_task(&mut w, 0, 1000.0, 100.0);
    w.start_task(t, vm(0), 1.0);
    let rate = w.task_rate(t);
    assert!((rate - 100.0).abs() < 1e-9, "rate {rate}");
    let done = w.advance(10.0);
    assert_eq!(done, vec![t]);
}

#[test]
fn slowdown_divides_rate() {
    let mut w = world();
    let t = add_task(&mut w, 0, 1000.0, 100.0);
    w.start_task(t, vm(0), 4.0);
    assert!((w.task_rate(t) - 25.0).abs() < 1e-9);
}

#[test]
fn vm_fair_share_caps_rate() {
    let mut w = world();
    let vm_mips = w.vms[vm(0)].mips;
    let t1 = add_task(&mut w, 0, 1e6, 1e9);
    let t2 = add_task(&mut w, 0, 1e6, 1e9);
    w.start_task(t1, vm(0), 1.0);
    w.start_task(t2, vm(0), 1.0);
    let r1 = w.task_rate(t1);
    assert!((r1 - vm_mips / 2.0).abs() < 1e-6, "r1 {r1} vm {vm_mips}");
}

#[test]
fn host_contention_scales_down() {
    let mut w = world();
    let h = host(0);
    // Saturate every VM on host 0 with one huge-demand task.
    let vms: Vec<_> = w.hosts[h].vms.clone();
    let mut tasks = Vec::new();
    for &v in &vms {
        let t = add_task(&mut w, 0, 1e9, 1e9);
        w.start_task(t, v, 1.0);
        tasks.push(t);
    }
    // Also background load to force capacity below demand.
    w.set_background_load(h, 0.5);
    let total_rate: f64 = tasks.iter().map(|&t| w.task_rate(t)).sum();
    let cap = w.hosts[h].effective_mips(0.0);
    assert!(total_rate <= cap * 1.001, "total {total_rate} cap {cap}");
    assert!(w.host_cpu_util(h) >= 0.99);
}

#[test]
fn advance_is_exact_piecewise() {
    let mut w = world();
    let t = add_task(&mut w, 0, 1000.0, 100.0);
    w.start_task(t, vm(0), 1.0);
    w.advance(3.0);
    assert!((w.task(t).remaining_mi - 700.0).abs() < 1e-9);
    assert!((w.task(t).progress() - 0.3).abs() < 1e-9);
    let eta = w.next_finish_time().unwrap();
    assert!((eta - 10.0).abs() < 1e-9);
}

#[test]
fn down_host_contributes_no_rate() {
    let mut w = world();
    let t = add_task(&mut w, 0, 1000.0, 100.0);
    w.start_task(t, vm(0), 1.0);
    let h = w.vms[vm(0)].host;
    // `set_host_down` self-marks the host dirty — no manual
    // `mark_rates_dirty` needed.
    w.set_host_down(h, 1e9);
    assert_eq!(w.task_rate(t), 0.0);
    assert!(w.next_finish_time().is_none());
    w.assert_consistent();
}

#[test]
fn availability_index_tracks_downtime_and_readiness() {
    let mut w = world();
    let n = w.vms.len();
    assert_eq!(w.available_vms().len(), n, "all VMs available at t=0");

    // Host goes down: its VMs leave the candidate list immediately.
    let h = w.vms[vm(0)].host;
    let on_host = w.hosts[h].vms.len();
    w.set_host_down(h, 40.0);
    assert_eq!(w.available_vms().len(), n - on_host);
    assert!(!w.vm_available(vm(0)));
    w.assert_consistent();

    // A VM elsewhere becomes unready.
    let other = *w.hosts[host(h.raw() + 1)].vms.first().unwrap();
    w.set_vm_ready_at(other, 25.0);
    assert_eq!(w.available_vms().len(), n - on_host - 1);
    w.assert_consistent();

    // Advancing past the wake times re-admits, in ascending id order.
    w.advance(30.0);
    assert!(w.vm_available(other));
    assert_eq!(w.available_vms().len(), n - on_host);
    w.advance(45.0);
    let avail = w.available_vms().into_owned();
    assert_eq!(avail.len(), n);
    assert!(avail.windows(2).all(|p| p[0] < p[1]), "ascending order");
    w.assert_consistent();
}

#[test]
fn overlapping_host_faults_keep_latest_recovery() {
    let mut w = world();
    let h = w.vms[vm(0)].host;
    // Second fault extends the outage; the first wake entry is stale.
    w.set_host_down(h, 20.0);
    w.set_host_down(h, 60.0);
    w.advance(25.0);
    assert!(!w.vm_available(vm(0)), "stale wake must not re-admit");
    w.assert_consistent();
    // And a shortened outage re-admits at the earlier time.
    w.set_host_down(h, 30.0);
    w.advance(31.0);
    assert!(w.vm_available(vm(0)));
    w.assert_consistent();
}

#[test]
fn load_aggregates_match_reference_arithmetic() {
    let mut w = world();
    let mut r = world();
    r.reference_scans = true;
    for (i, v) in [(0usize, 0usize), (1, 0), (2, 1), (3, 4)] {
        let len = 1000.0 + 7.0 * i as f64;
        let mips = 90.0 + 13.0 * i as f64;
        let a = add_task(&mut w, 0, len, mips);
        let b = add_task(&mut r, 0, len, mips);
        assert_eq!(a, b);
        w.start_task(a, vm(v), 1.0);
        r.start_task(b, vm(v), 1.0);
    }
    for hi in 0..w.hosts.len() {
        let h = host(hi);
        assert_eq!(w.host_cpu_util(h), r.host_cpu_util(h), "cpu host {h}");
        assert_eq!(w.host_ram_util(h), r.host_ram_util(h), "ram host {h}");
        assert_eq!(w.host_disk_util(h), r.host_disk_util(h), "disk host {h}");
        assert_eq!(w.host_bw_util(h), r.host_bw_util(h), "bw host {h}");
        assert_eq!(w.host_task_count(h), r.host_task_count(h), "count host {h}");
    }
    // Detach one and re-check: subtotals are recomputed, not drifted.
    w.complete_task(TaskId::new(1));
    r.complete_task(TaskId::new(1));
    for hi in 0..w.hosts.len() {
        let h = host(hi);
        assert_eq!(w.host_cpu_util(h), r.host_cpu_util(h), "cpu after detach {h}");
        assert_eq!(w.host_ram_util(h), r.host_ram_util(h), "ram after detach {h}");
    }
    w.assert_consistent();
}

#[test]
fn reset_task_restores_work_and_counts_restart() {
    let mut w = world();
    let t = add_task(&mut w, 0, 1000.0, 100.0);
    w.start_task(t, vm(0), 1.0);
    w.advance(5.0);
    w.reset_task(t, 30.0);
    assert_eq!(w.task(t).state, TaskState::Pending);
    assert_eq!(w.task(t).remaining_mi, 1000.0);
    assert_eq!(w.task(t).restarts, 1);
    assert_eq!(w.task(t).restart_time, 30.0);
    assert!(w.vms[vm(0)].tasks.is_empty());
    w.assert_consistent();
}

#[test]
fn complete_and_kill_detach_from_vm() {
    let mut w = world();
    let t1 = add_task(&mut w, 0, 1000.0, 100.0);
    let t2 = add_task(&mut w, 0, 1000.0, 100.0);
    w.start_task(t1, vm(0), 1.0);
    w.start_task(t2, vm(0), 1.0);
    w.advance(1.0);
    w.complete_task(t1);
    w.kill_task(t2);
    assert!(matches!(w.task(t1).state, TaskState::Completed { .. }));
    assert_eq!(w.task(t2).state, TaskState::Killed);
    assert!(w.vms[vm(0)].tasks.is_empty());
    assert_eq!(w.completed_log, vec![t1]);
    w.assert_consistent();
}

#[test]
fn best_mitigation_vm_prefers_low_straggler_ema() {
    let mut w = world();
    for h in &mut w.hosts {
        h.straggler_ema = 0.9;
    }
    let target_host = host(3);
    w.hosts[target_host].straggler_ema = 0.0;
    let v = w.best_mitigation_vm(None).unwrap();
    assert_eq!(w.vms[v].host, target_host);
    // excluding that host picks another one
    let v2 = w.best_mitigation_vm(Some(target_host)).unwrap();
    assert_ne!(w.vms[v2].host, target_host);
}

#[test]
fn straggler_ema_updates() {
    let mut w = world();
    w.note_straggler(host(0), true);
    assert!((w.hosts[host(0)].straggler_ema - 0.2).abs() < 1e-12);
    w.note_straggler(host(0), false);
    assert!((w.hosts[host(0)].straggler_ema - 0.16).abs() < 1e-12);
}

// ------------------------------------------------- index registry

#[test]
fn sets_track_lifecycle() {
    let mut w = world();
    let t1 = add_task(&mut w, 0, 1000.0, 100.0);
    let t2 = add_task(&mut w, 0, 1000.0, 100.0);
    assert_eq!(w.pending(), vec![t1, t2]);
    assert!(w.running().is_empty());
    assert_eq!(w.active_task_count(), 2);
    assert_eq!(w.job_active_count(job(0)), 2);

    w.start_task(t1, vm(0), 1.0);
    assert_eq!(w.pending(), vec![t2]);
    assert_eq!(w.running(), vec![t1]);

    assert!(w.hold_task(t2, 50.0));
    assert_eq!(w.held(), vec![t2]);
    assert!(w.pending().is_empty());
    assert_eq!(w.release_expired_holds(), 0);
    w.advance(50.0);
    assert_eq!(w.release_expired_holds(), 1);
    assert_eq!(w.pending(), vec![t2]);

    w.complete_task(t1);
    assert!(w.running().is_empty());
    assert_eq!(w.job_active_count(job(0)), 1);
    w.kill_task(t2);
    assert_eq!(w.active_task_count(), 0);
    assert_eq!(w.job_active_count(job(0)), 0);
    w.assert_consistent();
}

#[test]
fn active_job_set_follows_finish_job() {
    let mut w = world();
    let t = add_task(&mut w, 0, 1000.0, 100.0);
    w.add_job(mk_job(0, vec![t], false));
    assert!(w.has_active_jobs());
    assert_eq!(w.active_jobs(), vec![job(0)]);
    w.start_task(t, vm(0), 1.0);
    w.advance(10.0);
    w.complete_task(t);
    w.finish_job(job(0));
    assert!(!w.has_active_jobs());
    assert_eq!(w.active_job_count(), 0);
    assert!(matches!(w.job(job(0)).state, JobState::Done { .. }));
    w.assert_consistent();
}

#[test]
fn clone_map_tracks_single_live_clone() {
    let mut w = world();
    let orig = add_task(&mut w, 0, 1000.0, 100.0);
    w.start_task(orig, vm(0), 4.0);
    let clone_id = TaskId::new(w.n_tasks());
    w.add_task(Task {
        id: clone_id,
        job: job(0),
        length_mi: 1000.0,
        demand: w.task(orig).demand,
        state: TaskState::Pending,
        vm: None,
        last_vm: None,
        remaining_mi: 1000.0,
        submit_t: 0.0,
        first_start_t: None,
        restart_time: 0.0,
        restarts: 0,
        slowdown: 1.0,
        speculative_of: Some(orig),
        mitigated: true,
    });
    assert_eq!(w.clone_of(orig), Some(clone_id));
    assert_eq!(w.live_clone_count(), 1);
    w.kill_task(clone_id);
    assert_eq!(w.clone_of(orig), None);
    assert_eq!(w.live_clone_count(), 0);
    w.assert_consistent();
}

#[test]
fn finish_heap_matches_scan_minimum() {
    let mut w = world();
    let mut r = world();
    // Mirror worlds: identical ops, one indexed, one reference.
    r.reference_scans = true;
    for (len, mips, v, slow) in
        [(1000.0, 100.0, 0usize, 1.0), (4000.0, 200.0, 1, 2.0), (900.0, 50.0, 2, 1.0)]
    {
        let a = add_task(&mut w, 0, len, mips);
        let b = add_task(&mut r, 0, len, mips);
        assert_eq!(a, b);
        w.start_task(a, vm(v), slow);
        r.start_task(b, vm(v), slow);
    }
    let fast = w.next_finish_time();
    let slow = r.next_finish_time();
    assert_eq!(fast, slow, "heap vs scan minimum");
    // Advance both to the first finish and compare again.
    let te = fast.unwrap();
    assert_eq!(w.advance(te), r.advance(te));
    w.assert_consistent();
}

/// Satellite (§11): rate-consistency arm — an indexed world and a
/// reference world driven through identical random op sequences must
/// agree **bitwise** on every task rate and on `next_finish_time`
/// after every op, while `assert_consistent` recounts the maintained
/// rates (and the heap's live-entry coverage) against a from-scratch
/// reference pass.
#[test]
fn prop_rates_bitwise_match_reference_under_random_ops() {
    ptest::check("world-rate-consistency", 20, |rng| {
        let mut w = world();
        let mut r = world();
        r.reference_scans = true;
        let n_jobs = 2 + rng.below(3);
        for j in 0..n_jobs {
            let q = 1 + rng.below(5);
            let mut tasks = Vec::new();
            for _ in 0..q {
                let len = rng.range(500.0, 5000.0);
                let mips = rng.range(80.0, 400.0);
                let a = add_task(&mut w, j, len, mips);
                let b = add_task(&mut r, j, len, mips);
                assert_eq!(a, b);
                tasks.push(a);
            }
            for world in [&mut w, &mut r] {
                world.add_job(mk_job(j, tasks.clone(), false));
            }
        }
        for _ in 0..120 {
            match rng.below(8) {
                0 => {
                    let t = w.pending().first().copied();
                    if let Some(t) = t {
                        let v = vm(rng.below(w.vms.len()));
                        if w.vm_available(v) {
                            let slow = rng.range(1.0, 6.0);
                            w.start_task(t, v, slow);
                            r.start_task(t, v, slow);
                        }
                    }
                }
                1 => {
                    let t = pick(&mut w, rng, Which::Running);
                    if let Some(t) = t {
                        w.complete_task(t);
                        r.complete_task(t);
                    }
                }
                2 => {
                    let t = pick(&mut w, rng, Which::Running);
                    if let Some(t) = t {
                        w.kill_task(t);
                        r.kill_task(t);
                    }
                }
                3 => {
                    let t = pick(&mut w, rng, Which::Running);
                    if let Some(t) = t {
                        w.reset_task(t, 30.0);
                        r.reset_task(t, 30.0);
                    }
                }
                4 => {
                    let to = w.now + rng.range(0.1, 60.0);
                    let dw = w.advance(to);
                    let dr = r.advance(to);
                    if dw != dr {
                        return Err(format!("advance divergence: {dw:?} vs {dr:?}"));
                    }
                    for t in dw {
                        w.complete_task(t);
                        r.complete_task(t);
                    }
                }
                5 => {
                    let h = host(rng.below(w.hosts.len()));
                    let until = w.now + rng.range(1.0, 80.0);
                    w.set_host_down(h, until);
                    r.set_host_down(h, until);
                }
                6 => {
                    let h = host(rng.below(w.hosts.len()));
                    let load = rng.range(0.0, 0.6);
                    w.set_background_load(h, load);
                    r.set_background_load(h, load);
                }
                _ => {
                    let v = vm(rng.below(w.vms.len()));
                    let at = w.now + rng.range(1.0, 50.0);
                    w.set_vm_ready_at(v, at);
                    r.set_vm_ready_at(v, at);
                }
            }
            // Bitwise rate agreement for every task ever created.
            for ti in 0..w.n_tasks() {
                let t = TaskId::new(ti);
                let a = w.task_rate(t);
                let b = r.task_rate(t);
                if a.to_bits() != b.to_bits() {
                    return Err(format!("task {t} rate drift: indexed {a} reference {b}"));
                }
            }
            let (fa, fb) = (w.next_finish_time(), r.next_finish_time());
            if fa.map(f64::to_bits) != fb.map(f64::to_bits) {
                return Err(format!("next_finish_time drift: {fa:?} vs {fb:?}"));
            }
            w.assert_consistent();
        }
        Ok(())
    });
}

/// Which membership view to draw a random member from.
enum Which {
    Pending,
    Running,
}

/// Random member of a borrowed view, copied out before any mutation (the
/// explicit escape-hatch pattern the zero-alloc getters require).
fn pick(w: &mut World, rng: &mut crate::util::rng::Rng, which: Which) -> Option<TaskId> {
    let view = match which {
        Which::Pending => w.pending(),
        Which::Running => w.running(),
    };
    if view.is_empty() {
        None
    } else {
        Some(view[rng.below(view.len())])
    }
}

/// Satellite: property-style invariant check — pending/running/held and
/// per-job counters stay consistent with task states under random
/// place/hold/kill/complete/reset/speculate sequences.
#[test]
fn prop_indexes_consistent_under_random_ops() {
    ptest::check("world-index-consistency", 30, |rng| {
        let mut w = world();
        // Trace-consistency arm: record every transition and check,
        // after each random op, that the event stream recounts to the
        // same live sets as the world's indexes.
        #[cfg(feature = "sim-trace")]
        w.set_trace(TraceSink::mem());
        // 2–4 jobs with 1–5 tasks each.
        let n_jobs = 2 + rng.below(3);
        for j in 0..n_jobs {
            let q = 1 + rng.below(5);
            let mut tasks = Vec::new();
            for _ in 0..q {
                tasks.push(add_task(&mut w, j, rng.range(500.0, 5000.0), rng.range(80.0, 400.0)));
            }
            let dd = rng.chance(0.5);
            w.add_job(mk_job(j, tasks, dd));
        }
        for _ in 0..150 {
            match rng.below(11) {
                0 => {
                    // place a pending task
                    let t = w.pending().first().copied();
                    if let Some(t) = t {
                        let v = vm(rng.below(w.vms.len()));
                        if w.vm_available(v) {
                            w.start_task(t, v, rng.range(1.0, 6.0));
                        }
                    }
                }
                1 => {
                    if let Some(t) = pick(&mut w, rng, Which::Running) {
                        w.complete_task(t);
                    }
                }
                2 => {
                    if let Some(t) = pick(&mut w, rng, Which::Running) {
                        w.kill_task(t);
                    }
                }
                3 => {
                    if let Some(t) = pick(&mut w, rng, Which::Running) {
                        w.reset_task(t, 30.0);
                    }
                }
                4 => {
                    if let Some(t) = pick(&mut w, rng, Which::Pending) {
                        let until = w.now + rng.range(1.0, 100.0);
                        w.hold_task(t, until);
                    }
                }
                5 => {
                    let dt = rng.range(0.1, 60.0);
                    let to = w.now + dt;
                    for t in w.advance(to) {
                        w.complete_task(t);
                    }
                    w.release_expired_holds();
                }
                6 => {
                    // speculate a running original via the mitigation path
                    let orig = w
                        .running()
                        .iter()
                        .copied()
                        .find(|&t| w.task(t).speculative_of.is_none() && w.clone_of(t).is_none());
                    if let Some(t) = orig {
                        let _ = crate::mitigation::speculate(&mut w, t, rng.range(1.0, 3.0));
                    }
                }
                7 => {
                    // close out jobs whose tasks are all inactive
                    let jobs = w.active_jobs().into_owned();
                    for j in jobs {
                        if w.job_active_count(j) == 0 {
                            w.finish_job(j);
                        }
                    }
                }
                8 => {
                    // host fault (possibly overlapping a live outage)
                    let h = host(rng.below(w.hosts.len()));
                    let until = w.now + rng.range(1.0, 80.0);
                    w.set_host_down(h, until);
                }
                9 => {
                    // VM readiness delay (VmCreation-style fault)
                    let v = vm(rng.below(w.vms.len()));
                    let at = w.now + rng.range(1.0, 50.0);
                    w.set_vm_ready_at(v, at);
                }
                _ => {
                    // background-load shift (rate-change event)
                    let h = host(rng.below(w.hosts.len()));
                    let load = rng.range(0.0, 0.6);
                    w.set_background_load(h, load);
                }
            }
            w.assert_consistent();
            #[cfg(feature = "sim-trace")]
            {
                let rc = crate::sim::trace::recount(w.trace_events());
                if rc.pending.as_slice() != w.pending().as_ref()
                    || rc.running.as_slice() != w.running().as_ref()
                    || rc.held.as_slice() != w.held().as_ref()
                    || rc.active_jobs.as_slice() != w.active_jobs().as_ref()
                {
                    return Err(format!(
                        "event recount disagrees with live sets: {rc:?} vs \
                         pending={:?} running={:?} held={:?} jobs={:?}",
                        w.pending(),
                        w.running(),
                        w.held(),
                        w.active_jobs()
                    ));
                }
            }
        }
        // Accessors agree with a forced reference re-scan — including
        // the load aggregates and the availability index, bitwise.
        let pend = w.pending().into_owned();
        let run = w.running().into_owned();
        let held = w.held().into_owned();
        let jobs = w.active_jobs().into_owned();
        let avail = w.available_vms().into_owned();
        let utils: Vec<(f64, f64, f64, f64, usize)> = (0..w.hosts.len())
            .map(|hi| {
                let h = host(hi);
                (
                    w.host_cpu_util(h),
                    w.host_ram_util(h),
                    w.host_disk_util(h),
                    w.host_bw_util(h),
                    w.host_task_count(h),
                )
            })
            .collect();
        w.reference_scans = true;
        if pend != w.pending().into_owned()
            || run != w.running().into_owned()
            || held != w.held().into_owned()
            || jobs != w.active_jobs().into_owned()
        {
            return Err("indexed accessors disagree with reference scans".into());
        }
        if avail != w.available_vms().into_owned() {
            return Err("availability index disagrees with reference scan".into());
        }
        for (hi, &(cpu, ram, disk, bw, n)) in utils.iter().enumerate() {
            let h = host(hi);
            let refer =
                (w.host_cpu_util(h), w.host_ram_util(h), w.host_disk_util(h), w.host_bw_util(h));
            if (cpu, ram, disk, bw) != refer {
                return Err(format!(
                    "host {h} aggregates disagree: indexed {:?} reference {refer:?}",
                    (cpu, ram, disk, bw)
                ));
            }
            if n != w.host_task_count(h) {
                return Err(format!("host {h} task count disagrees"));
            }
        }
        Ok(())
    });
}

/// Satellite (ids): the borrowed-view getters — the zero-alloc slices the
/// tentpole introduced — must stay sorted, duplicate-free, and equal to a
/// from-scratch recount over `debug_tasks`/`debug_jobs` after every
/// random op, and `active_tasks(job)` must enumerate exactly the active
/// originals of each job's task list.
#[test]
fn prop_borrowed_views_match_reference_recount() {
    ptest::check("world-borrowed-views", 20, |rng| {
        let mut w = world();
        let n_jobs = 2 + rng.below(3);
        for j in 0..n_jobs {
            let q = 1 + rng.below(5);
            let mut tasks = Vec::new();
            for _ in 0..q {
                tasks.push(add_task(&mut w, j, rng.range(500.0, 5000.0), rng.range(80.0, 400.0)));
            }
            w.add_job(mk_job(j, tasks, false));
        }
        for _ in 0..80 {
            match rng.below(6) {
                0 => {
                    let t = w.pending().first().copied();
                    if let Some(t) = t {
                        let v = vm(rng.below(w.vms.len()));
                        if w.vm_available(v) {
                            w.start_task(t, v, rng.range(1.0, 6.0));
                        }
                    }
                }
                1 => {
                    if let Some(t) = pick(&mut w, rng, Which::Running) {
                        w.complete_task(t);
                    }
                }
                2 => {
                    if let Some(t) = pick(&mut w, rng, Which::Running) {
                        w.reset_task(t, 30.0);
                    }
                }
                3 => {
                    if let Some(t) = pick(&mut w, rng, Which::Pending) {
                        let until = w.now + rng.range(1.0, 50.0);
                        w.hold_task(t, until);
                    }
                }
                4 => {
                    let to = w.now + rng.range(0.1, 40.0);
                    for t in w.advance(to) {
                        w.complete_task(t);
                    }
                    w.release_expired_holds();
                }
                _ => {
                    let h = host(rng.below(w.hosts.len()));
                    w.set_background_load(h, rng.range(0.0, 0.6));
                }
            }
            // Recount every view from the O(total) debug walk.
            let recount = |pred: &dyn Fn(&Task) -> bool| -> Vec<TaskId> {
                w.debug_tasks().iter().filter(|t| pred(t)).map(|t| t.id).collect()
            };
            let pend = recount(&|t| t.state == TaskState::Pending);
            let run = recount(&|t| t.is_running());
            let held = recount(&|t| matches!(t.state, TaskState::Held { .. }));
            for (name, view, expect) in [
                ("pending", w.pending(), &pend),
                ("running", w.running(), &run),
                ("held", w.held(), &held),
            ] {
                if view.as_ref() != expect.as_slice() {
                    return Err(format!("{name} view drift: {view:?} vs {expect:?}"));
                }
                if !view.windows(2).all(|p| p[0] < p[1]) {
                    return Err(format!("{name} view not strictly ascending"));
                }
            }
            let jobs: Vec<JobId> =
                w.debug_jobs().iter().filter(|j| j.is_active()).map(|j| j.id).collect();
            if w.active_jobs().as_ref() != jobs.as_slice() {
                return Err("active_jobs view drift".into());
            }
            for j in w.debug_jobs() {
                let expect: Vec<TaskId> = j
                    .tasks
                    .iter()
                    .copied()
                    .filter(|&t| w.task(t).is_active())
                    .collect();
                let got: Vec<TaskId> = w.active_tasks(j.id).collect();
                if got != expect {
                    return Err(format!("active_tasks({}) drift: {got:?} vs {expect:?}", j.id));
                }
            }
        }
        Ok(())
    });
}
