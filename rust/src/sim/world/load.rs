//! Incremental resource-load accounting and the VM-availability index
//! (DESIGN.md §9).
//!
//! Owns two invariants:
//!
//! * **Load caches are the reference fold, bit for bit.**  Every VM
//!   carries a cached demand subtotal ([`ResLoad`]) recomputed from
//!   scratch with the reference arithmetic whenever its resident task set
//!   changes (never adjusted by ±delta, which would drift under float
//!   non-associativity), and every host carries the fold of its VMs'
//!   subtotals in `host.vms` order — the exact grouping the reference
//!   scans use.  `host_cpu_util` / `host_ram_util` / `host_disk_util` /
//!   `host_bw_util` / `host_task_count` are then O(1) reads.
//!
//! * **The availability set is exact at every query point.**  Membership
//!   (`vm_available`: ready and on an up host) is reconciled on every
//!   readiness/fault transition, and a wake-time min-heap re-admits VMs
//!   as `now` advances.  Because the set is an always-sorted [`IdSet`],
//!   `available_vms` borrows it directly — same content and order as the
//!   reference `0..vms.len()` filter scan, with no per-call allocation.

use crate::sim::types::*;
use crate::sim::world::ids::{Arena, IdSet};
use crate::sim::world::rates::EtaKey;
use crate::sim::world::World;
use std::borrow::Cow;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Cached resource-demand subtotal for one VM (or the fold of a host's
/// VMs).  `mips` is the fair-share-capped CPU demand (`vm_demand`);
/// ram/disk/bw are plain sums of resident task demand.
#[derive(Clone, Copy, Default, PartialEq, Debug)]
pub(super) struct ResLoad {
    pub(super) mips: f64,
    pub(super) ram_gb: f64,
    pub(super) disk_gb: f64,
    pub(super) bw_kbps: f64,
}

/// Per-VM/per-host load caches + the availability index.
pub(super) struct LoadIndex {
    /// Per-VM cached demand subtotals, refreshed whenever the VM's task
    /// set changes (place/complete/kill/reset/hold-release).
    pub(super) vm: Arena<VmId, ResLoad>,
    /// Per-host fold of its VMs' subtotals in `host.vms` order.
    pub(super) host: Arena<HostId, ResLoad>,
    /// Per-host resident-task counter (`host_task_count` in O(1)).
    pub(super) host_tasks: Arena<HostId, usize>,
    /// VMs currently placeable (`vm_available`): ready and on an up host.
    /// Always sorted, so it doubles as the candidate list the reference
    /// `0..vms.len()` filter scan would produce.
    pub(super) avail: IdSet<VmId>,
    /// Min-heap of (wake time, vm) for VMs that left the available set:
    /// wake = max(ready_at, down_until).  Popped as `now` advances.
    /// Duplicates are allowed (a VM hit by several faults pushes several
    /// entries); stale pops are filtered against live state.
    pub(super) suspend_heap: BinaryHeap<Reverse<(EtaKey, VmId)>>,
}

impl LoadIndex {
    /// Empty caches for a fresh fleet.  At t = 0 every VM is ready
    /// (`ready_at == 0.0`) on an up host, so the availability index
    /// starts full.
    pub(super) fn new(n_hosts: usize, n_vms: usize) -> LoadIndex {
        let mut avail = IdSet::new();
        for v in 0..n_vms {
            avail.insert(VmId::new(v));
        }
        LoadIndex {
            vm: (0..n_vms).map(|_| ResLoad::default()).collect(),
            host: (0..n_hosts).map(|_| ResLoad::default()).collect(),
            host_tasks: (0..n_hosts).map(|_| 0).collect(),
            avail,
            suspend_heap: BinaryHeap::new(),
        }
    }
}

impl World {
    /// Sum of task MIPS demand currently on a VM (capped per task by fair
    /// share).  O(1) via the cached subtotal; reference mode recomputes.
    pub(super) fn vm_demand(&self, vm: VmId) -> f64 {
        if self.reference_scans {
            let v = &self.vms[vm];
            let n = v.tasks.len().max(1) as f64;
            let fair = v.mips / n;
            return v
                .tasks
                .iter()
                .map(|&t| self.registry.tasks[t].demand.mips.min(fair).max(1.0))
                .sum();
        }
        self.load.vm[vm].mips
    }

    /// Host CPU utilization in [0, 1] including background + reserved load.
    /// O(1) via the per-host aggregate; reference mode re-sums per VM.
    pub fn host_cpu_util(&self, host: HostId) -> f64 {
        let h = &self.hosts[host];
        if !h.is_up(self.now) {
            return 0.0;
        }
        let demand: f64 = if self.reference_scans {
            h.vms.iter().map(|&v| self.vm_demand(v)).sum()
        } else {
            self.load.host[host].mips
        };
        (demand / h.mips_total + h.background_load + self.reserved_util).min(1.0)
    }

    /// Host RAM utilization in [0, 1].  Both modes group the sum per VM
    /// (subtotal-then-fold) so the arithmetic is bitwise shared.
    pub fn host_ram_util(&self, host: HostId) -> f64 {
        let h = &self.hosts[host];
        let used: f64 = if self.reference_scans {
            // Grouped per VM (not one flat sum over all host tasks) so the
            // fold order matches the indexed subtotal-then-aggregate path.
            h.vms
                .iter()
                .map(|&v| {
                    self.vms[v]
                        .tasks
                        .iter()
                        .map(|&t| self.registry.tasks[t].demand.ram_gb)
                        .sum::<f64>()
                })
                .sum()
        } else {
            self.load.host[host].ram_gb
        };
        (used / h.ram_gb + 0.5 * h.background_load + 0.5 * self.reserved_util).min(1.0)
    }

    /// Host disk utilization in [0, 1].
    pub fn host_disk_util(&self, host: HostId) -> f64 {
        let h = &self.hosts[host];
        let used: f64 = if self.reference_scans {
            h.vms
                .iter()
                .map(|&v| {
                    self.vms[v]
                        .tasks
                        .iter()
                        .map(|&t| self.registry.tasks[t].demand.disk_gb)
                        .sum::<f64>()
                })
                .sum()
        } else {
            self.load.host[host].disk_gb
        };
        (used / h.disk_gb + 0.3 * self.reserved_util).min(1.0)
    }

    /// Host network utilization in [0, 1].
    pub fn host_bw_util(&self, host: HostId) -> f64 {
        let h = &self.hosts[host];
        let used: f64 = if self.reference_scans {
            h.vms
                .iter()
                .map(|&v| {
                    self.vms[v]
                        .tasks
                        .iter()
                        .map(|&t| self.registry.tasks[t].demand.bw_kbps)
                        .sum::<f64>()
                })
                .sum()
        } else {
            self.load.host[host].bw_kbps
        };
        (used / h.bw_kbps.max(1e-9) + 0.3 * self.reserved_util).min(1.0)
    }

    /// Number of resident tasks on a host (counter-backed).
    pub fn host_task_count(&self, host: HostId) -> usize {
        if self.reference_scans {
            return self.hosts[host].vms.iter().map(|&v| self.vms[v].tasks.len()).sum();
        }
        self.load.host_tasks[host]
    }

    /// Reference-arithmetic demand subtotal of one VM: fair-share-capped
    /// MIPS plus plain ram/disk/bw sums, folded in `vm.tasks` order.
    /// This is the **single definition** both modes share — the indexed
    /// caches are always produced by this exact fold.
    pub(super) fn compute_vm_load(&self, vm: VmId) -> ResLoad {
        let v = &self.vms[vm];
        let n = v.tasks.len().max(1) as f64;
        let fair = v.mips / n;
        let mut l = ResLoad::default();
        for &t in &v.tasks {
            let d = &self.registry.tasks[t].demand;
            l.mips += d.mips.min(fair).max(1.0);
            l.ram_gb += d.ram_gb;
            l.disk_gb += d.disk_gb;
            l.bw_kbps += d.bw_kbps;
        }
        l
    }

    /// Refresh one VM's cached subtotal and re-fold its host's aggregate
    /// (in `host.vms` order, matching the reference grouping bit for bit).
    /// Called on every task placement/detachment; O(tasks-on-vm +
    /// vms-on-host), independent of fleet size.
    pub(super) fn refresh_vm_load(&mut self, vm: VmId) {
        self.load.vm[vm] = self.compute_vm_load(vm);
        let host = self.vms[vm].host;
        let mut agg = ResLoad::default();
        for &v in &self.hosts[host].vms {
            let l = &self.load.vm[v];
            agg.mips += l.mips;
            agg.ram_gb += l.ram_gb;
            agg.disk_gb += l.disk_gb;
            agg.bw_kbps += l.bw_kbps;
        }
        self.load.host[host] = agg;
    }

    // ----------------------------------------------- availability index

    /// Reconcile one VM's membership in the availability index with its
    /// live state; schedules a wake-up when it is currently unavailable.
    pub(super) fn refresh_vm_availability(&mut self, vm: VmId) {
        if self.reference_scans {
            return;
        }
        if self.vm_available(vm) {
            self.load.avail.insert(vm);
        } else {
            self.load.avail.remove(vm);
            // Wake time is strictly in the future whenever the VM is
            // unavailable, so re-popping the same entry cannot loop.
            let wake = self.vm_wake_time(vm);
            self.load.suspend_heap.push(Reverse((EtaKey(wake), vm)));
        }
    }

    /// Pop matured wake-ups as `now` advances and re-admit their VMs.
    /// Stale entries (VM re-suspended with a later wake, or already
    /// re-admitted via an earlier duplicate) are filtered by re-checking
    /// live state.
    pub(super) fn sync_availability(&mut self) {
        if self.reference_scans {
            return;
        }
        while let Some(&Reverse((EtaKey(wake), vm))) = self.load.suspend_heap.peek() {
            if wake > self.now {
                break;
            }
            self.load.suspend_heap.pop();
            if !self.load.avail.contains(vm) {
                self.refresh_vm_availability(vm);
            }
        }
    }

    /// Currently placeable VMs in ascending id order — the scheduler
    /// candidate list.  Indexed mode borrows the always-sorted member set
    /// (zero-alloc); reference mode materializes the seed's full filter
    /// scan.  Content and order are identical, so downstream RNG streams
    /// (Random/A3C sampling) cannot diverge between modes.
    pub fn available_vms(&self) -> Cow<'_, [VmId]> {
        if self.reference_scans {
            let n = self.vms.len();
            return Cow::Owned(
                (0..n).map(VmId::new).filter(|&v| self.vm_available(v)).collect(),
            );
        }
        Cow::Borrowed(self.load.avail.as_slice())
    }

    /// Layer check (§9): load caches must match a from-scratch recount
    /// **bitwise** — the caches are defined as the reference fold, not an
    /// approximation of it — and the availability set must equal the
    /// reference filter scan.  Only meaningful in indexed mode (reference
    /// mode maintains neither).
    pub(super) fn assert_loads_consistent(&self) {
        for v in 0..self.vms.len() {
            let v = VmId::new(v);
            let expect = self.compute_vm_load(v);
            assert!(
                self.load.vm[v] == expect,
                "vm {v} load drift: cached {:?} recount {expect:?}",
                self.load.vm[v]
            );
        }
        for h in self.hosts.iter() {
            let mut agg = ResLoad::default();
            let mut ntasks = 0usize;
            for &v in &h.vms {
                let l = self.compute_vm_load(v);
                agg.mips += l.mips;
                agg.ram_gb += l.ram_gb;
                agg.disk_gb += l.disk_gb;
                agg.bw_kbps += l.bw_kbps;
                ntasks += self.vms[v].tasks.len();
            }
            let hid = h.id;
            assert!(
                self.load.host[hid] == agg,
                "host {hid} load drift: cached {:?} recount {agg:?}",
                self.load.host[hid]
            );
            assert_eq!(self.load.host_tasks[hid], ntasks, "host {hid} task-counter drift");
        }
        // The availability index is exact whenever `now` last moved
        // through `advance` (which syncs) — tests that poke `now`
        // directly must not call this.
        let avail: Vec<VmId> =
            (0..self.vms.len()).map(VmId::new).filter(|&v| self.vm_available(v)).collect();
        assert_eq!(self.load.avail.as_slice(), avail, "availability set drift");
    }
}
