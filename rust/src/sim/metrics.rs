//! QoS metrics (paper §4.1, Eqs. 6–14), collected per scheduling interval
//! and aggregated over the run.

use crate::sim::types::*;
use crate::sim::world::World;
use crate::util::stats::{mape, Summary};

/// Snapshot of one scheduling interval.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct IntervalMetrics {
    pub t: f64,
    /// Eq. 7 energy over the interval, kWh.
    pub energy_kwh: f64,
    /// Fleet-mean utilizations (up hosts only), Eqs. 10–12 + CPU.
    pub cpu_util: f64,
    pub ram_util: f64,
    pub disk_util: f64,
    pub net_util: f64,
    /// Eq. 9 resource contention (normalized demand units on overloaded
    /// resources).
    pub contention: f64,
    pub active_tasks: usize,
    pub hosts_down: usize,
}

/// Whole-run aggregation.
#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    pub intervals: Vec<IntervalMetrics>,
    /// Per completed original task: response time T_C − T_S (Eq. 8 term 1).
    pub exec_times: Vec<f64>,
    /// Per completed original task: restart overhead R_i (Eq. 8 term 2).
    pub restart_times: Vec<f64>,
    /// Completion timestamps (Fig. 8 series).
    pub completion_times: Vec<f64>,
    /// Weighted SLA violations and total weight (Eq. 13).
    pub sla_violated_weight: f64,
    pub sla_total_weight: f64,
    /// Straggler prediction records per job: (predicted E_S, actual count).
    pub straggler_pred: Vec<(f64, f64)>,
    /// Straggler classification confusion (Fig. 2 F1).
    pub confusion: crate::util::stats::Confusion,
    /// Wall-time attribution of each interval phase (Fig. 10 overhead is
    /// derived from the predict+mitigate counters — see
    /// [`RunMetrics::manager_overhead_s`]).
    pub profile: crate::sim::trace::PhaseProfile,
    /// Per-mitigation latency: time from task start to the mitigation
    /// action (Fig. 5's detection+mitigation delay).
    pub mitigation_delays: Vec<f64>,
    /// Extra (cloned/speculative) task executions launched.
    pub speculations: u64,
    pub reruns: u64,
    pub jobs_done: usize,
    pub tasks_done: usize,
}

impl RunMetrics {
    /// Snapshot interval metrics from the world (call once per interval).
    pub fn snapshot(&mut self, w: &World, interval_s: f64) {
        let mut m = IntervalMetrics { t: w.now, ..Default::default() };
        let mut up = 0usize;
        let mut energy_w = 0.0;
        for h in &w.hosts {
            if !h.is_up(w.now) {
                m.hosts_down += 1;
                continue;
            }
            up += 1;
            let cpu = w.host_cpu_util(h.id);
            let ram = w.host_ram_util(h.id);
            let disk = w.host_disk_util(h.id);
            let net = w.host_bw_util(h.id);
            m.cpu_util += cpu;
            m.ram_util += ram;
            m.disk_util += disk;
            m.net_util += net;
            // Eq. 7: U_k·(E_max − E_min) + E_min, summed over hosts.
            energy_w += cpu * (h.power_peak_w - h.power_idle_w) + h.power_idle_w;
            // Eq. 9: when a resource is overloaded, add the task demand
            // normalized by the host capacity.
            let demand_over = |util: f64| util >= 0.999;
            if demand_over(cpu) || demand_over(ram) || demand_over(net) {
                for &v in &h.vms {
                    for &t in &w.vms[v].tasks {
                        let d = &w.task(t).demand;
                        if demand_over(cpu) {
                            m.contention += d.mips / h.mips_total;
                        }
                        if demand_over(ram) {
                            m.contention += d.ram_gb / h.ram_gb;
                        }
                        if demand_over(net) {
                            m.contention += d.bw_kbps / h.bw_kbps.max(1e-9);
                        }
                    }
                }
            }
        }
        if up > 0 {
            m.cpu_util /= up as f64;
            m.ram_util /= up as f64;
            m.disk_util /= up as f64;
            m.net_util /= up as f64;
        }
        m.energy_kwh = energy_w * interval_s / 3.6e6;
        m.active_tasks = w.active_task_count();
        self.intervals.push(m);
    }

    /// Record a completed original (non-speculative) task.
    pub fn record_task_done(&mut self, task: &Task, t_complete: f64) {
        self.exec_times.push(t_complete - task.submit_t);
        self.restart_times.push(task.restart_time);
        self.completion_times.push(t_complete);
        self.tasks_done += 1;
    }

    /// Record job completion with its SLA outcome and prediction score.
    pub fn record_job_done(
        &mut self,
        job: &Job,
        t_complete: f64,
        predicted_stragglers: f64,
        actual_stragglers: usize,
    ) {
        self.sla_total_weight += job.sla_weight;
        if t_complete > job.sla_deadline {
            self.sla_violated_weight += job.sla_weight;
        }
        self.straggler_pred.push((predicted_stragglers, actual_stragglers as f64));
        self.jobs_done += 1;
    }

    /// Fig. 10's manager overhead: wall-clock seconds spent inside the
    /// straggler manager (prediction + mitigation).  The single shared
    /// definition — the phase profiler's predict+mitigate counters; the
    /// engine times those phases with contiguous `Instant`s, so the sum
    /// spans exactly the old lump measurement around the manager block.
    pub fn manager_overhead_s(&self) -> f64 {
        self.profile.manager_overhead_s()
    }

    /// First mismatch between two runs over every *deterministic* field
    /// (wall-clock — `profile` — is measurement, not simulation state,
    /// and is excluded).  Comparisons are bitwise (`==` on f64): the
    /// parity contract between indexed/reference worlds and between a
    /// live run and `trace::replay` is exactness, not tolerance.
    pub fn diff_deterministic(&self, other: &RunMetrics) -> Option<String> {
        fn ne<T: PartialEq + std::fmt::Debug>(field: &str, a: &T, b: &T) -> Option<String> {
            (a != b).then(|| format!("{field}: {a:?} vs {b:?}"))
        }
        if self.intervals.len() != other.intervals.len() {
            return Some(format!(
                "intervals.len: {} vs {}",
                self.intervals.len(),
                other.intervals.len()
            ));
        }
        for (i, (a, b)) in self.intervals.iter().zip(&other.intervals).enumerate() {
            if a != b {
                return Some(format!("intervals[{i}]: {a:?} vs {b:?}"));
            }
        }
        ne("exec_times", &self.exec_times, &other.exec_times)
            .or_else(|| ne("restart_times", &self.restart_times, &other.restart_times))
            .or_else(|| ne("completion_times", &self.completion_times, &other.completion_times))
            .or_else(|| {
                ne("sla_violated_weight", &self.sla_violated_weight, &other.sla_violated_weight)
            })
            .or_else(|| ne("sla_total_weight", &self.sla_total_weight, &other.sla_total_weight))
            .or_else(|| ne("straggler_pred", &self.straggler_pred, &other.straggler_pred))
            .or_else(|| ne("confusion.tp", &self.confusion.tp, &other.confusion.tp))
            .or_else(|| ne("confusion.fp", &self.confusion.fp, &other.confusion.fp))
            .or_else(|| ne("confusion.fn", &self.confusion.fn_, &other.confusion.fn_))
            .or_else(|| ne("confusion.tn", &self.confusion.tn, &other.confusion.tn))
            .or_else(|| {
                ne("mitigation_delays", &self.mitigation_delays, &other.mitigation_delays)
            })
            .or_else(|| ne("speculations", &self.speculations, &other.speculations))
            .or_else(|| ne("reruns", &self.reruns, &other.reruns))
            .or_else(|| ne("jobs_done", &self.jobs_done, &other.jobs_done))
            .or_else(|| ne("tasks_done", &self.tasks_done, &other.tasks_done))
    }

    /// Panic with the first mismatching field (test helper shared by the
    /// world-parity and trace-replay suites).
    pub fn assert_deterministic_eq(&self, other: &RunMetrics, label: &str) {
        if let Some(diff) = self.diff_deterministic(other) {
            panic!("[{label}] metrics diverge — {diff}");
        }
    }

    // ------------------------------------------------------- aggregates

    /// Eq. 8: mean response time + mean restart overhead, seconds.
    pub fn avg_execution_time(&self) -> f64 {
        if self.exec_times.is_empty() {
            return 0.0;
        }
        let n = self.exec_times.len() as f64;
        self.exec_times.iter().sum::<f64>() / n + self.restart_times.iter().sum::<f64>() / n
    }

    /// Eq. 13: weighted SLA violation rate in [0, 1].
    pub fn sla_violation_rate(&self) -> f64 {
        if self.sla_total_weight == 0.0 {
            0.0
        } else {
            self.sla_violated_weight / self.sla_total_weight
        }
    }

    /// Total energy (Eq. 7 summed), kWh.
    pub fn total_energy_kwh(&self) -> f64 {
        self.intervals.iter().map(|m| m.energy_kwh).sum()
    }

    /// Mean Eq. 9 contention per interval.
    pub fn avg_contention(&self) -> f64 {
        if self.intervals.is_empty() {
            return 0.0;
        }
        self.intervals.iter().map(|m| m.contention).sum::<f64>() / self.intervals.len() as f64
    }

    /// Fleet-mean utilizations over the run (cpu, ram, disk, net).
    pub fn avg_utils(&self) -> (f64, f64, f64, f64) {
        if self.intervals.is_empty() {
            return (0.0, 0.0, 0.0, 0.0);
        }
        let n = self.intervals.len() as f64;
        (
            self.intervals.iter().map(|m| m.cpu_util).sum::<f64>() / n,
            self.intervals.iter().map(|m| m.ram_util).sum::<f64>() / n,
            self.intervals.iter().map(|m| m.disk_util).sum::<f64>() / n,
            self.intervals.iter().map(|m| m.net_util).sum::<f64>() / n,
        )
    }

    /// Eq. 14 MAPE of straggler-count prediction over jobs with ≥ 1 actual
    /// straggler.
    pub fn straggler_mape(&self) -> f64 {
        let actual: Vec<f64> = self.straggler_pred.iter().map(|p| p.1).collect();
        let pred: Vec<f64> = self.straggler_pred.iter().map(|p| p.0).collect();
        mape(&actual, &pred)
    }

    /// Summary of task response times (Fig. 8 variance bars).
    pub fn exec_summary(&self) -> Summary {
        Summary::of(&self.exec_times)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::sim::types::{TaskDemand, TaskState};
    use crate::sim::world::World;

    fn world_with_task() -> (World, TaskId) {
        let mut w = World::new(&SimConfig::test_defaults());
        let id = TaskId::new(0);
        w.add_task(Task {
            id,
            job: JobId::new(0),
            length_mi: 100.0,
            demand: TaskDemand { mips: 100.0, ram_gb: 0.2, disk_gb: 1.0, bw_kbps: 0.2 },
            state: TaskState::Pending,
            vm: None,
            last_vm: None,
            remaining_mi: 100.0,
            submit_t: 0.0,
            first_start_t: None,
            restart_time: 12.0,
            restarts: 1,
            slowdown: 1.0,
            speculative_of: None,
            mitigated: false,
        });
        (w, id)
    }

    #[test]
    fn energy_in_idle_band() {
        let (w, _) = world_with_task();
        let mut rm = RunMetrics::default();
        rm.snapshot(&w, 300.0);
        let m = &rm.intervals[0];
        // Idle fleet: energy = Σ idle watts × 300 s.
        let idle_w: f64 = w.hosts.iter().map(|h| h.power_idle_w).sum();
        let expect = idle_w * 300.0 / 3.6e6;
        assert!((m.energy_kwh - expect).abs() < 1e-9, "{} vs {expect}", m.energy_kwh);
        assert_eq!(m.hosts_down, 0);
        assert!(m.contention == 0.0);
    }

    #[test]
    fn energy_grows_with_load() {
        let (mut w, t) = world_with_task();
        let mut rm = RunMetrics::default();
        rm.snapshot(&w, 300.0);
        w.start_task(t, VmId::new(0), 1.0);
        rm.snapshot(&w, 300.0);
        assert!(rm.intervals[1].energy_kwh > rm.intervals[0].energy_kwh);
    }

    #[test]
    fn contention_counts_overloaded_host() {
        let (mut w, t) = world_with_task();
        w.start_task(t, VmId::new(0), 1.0);
        w.set_background_load(HostId::new(0), 0.995); // force cpu util to 1.0
        let mut rm = RunMetrics::default();
        rm.snapshot(&w, 300.0);
        assert!(rm.intervals[0].contention > 0.0);
    }

    #[test]
    fn avg_execution_time_eq8() {
        let (w, t) = world_with_task();
        let mut rm = RunMetrics::default();
        rm.record_task_done(w.task(t), 50.0);
        // T_C − T_S = 50, R = 12.
        assert!((rm.avg_execution_time() - 62.0).abs() < 1e-12);
    }

    #[test]
    fn sla_rate_weighted() {
        let mut rm = RunMetrics::default();
        let mk_job = |w: f64, deadline: f64| Job {
            id: JobId::new(0),
            tasks: vec![],
            submit_t: 0.0,
            deadline_driven: true,
            sla_deadline: deadline,
            sla_weight: w,
            state: JobState::Active,
            true_alpha: 2.0,
            true_beta: 1.0,
        };
        rm.record_job_done(&mk_job(1.0, 100.0), 150.0, 1.0, 1); // violated
        rm.record_job_done(&mk_job(3.0, 100.0), 50.0, 0.0, 0); // met
        assert!((rm.sla_violation_rate() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn mape_over_jobs() {
        let mut rm = RunMetrics::default();
        rm.straggler_pred = vec![(2.0, 2.0), (1.0, 2.0)];
        assert!((rm.straggler_mape() - 25.0).abs() < 1e-9);
    }

    #[test]
    fn down_host_excluded_from_utils() {
        let (mut w, _) = world_with_task();
        let n = w.hosts.len();
        for h in 0..n - 1 {
            w.set_host_down(HostId::new(h), 1e9);
        }
        let mut rm = RunMetrics::default();
        rm.snapshot(&w, 300.0);
        assert_eq!(rm.intervals[0].hosts_down, n - 1);
    }
}
