//! The simulation engine: event loop, interval orchestration, fault
//! handling, mitigation application, and the `Manager` interface that all
//! straggler techniques implement.
//!
//! One `Simulation` = one run of one technique under one config.  The
//! coordinator (`coordinator::run`) builds the right manager/scheduler
//! pair and drives this engine.

use crate::config::SimConfig;
use crate::mitigation::{self, Action};
use crate::predictor::FeatureExtractor;
use crate::runtime::Manifest;
use crate::sim::faults::{Fault, FaultInjector};
use crate::sim::metrics::RunMetrics;
use crate::sim::trace::{Event, FaultEvent, MitigationKind, Phase, TraceSink};
use crate::sim::types::*;
use crate::sim::world::World;
use crate::trace::generative::Generative;
use crate::trace::planetlab::{PlanetLabTrace, TraceParams};
use crate::trace::workload::{JobSpec, WorkloadGenerator};
use crate::util::rng::Pcg;
use std::time::Instant;

/// Ground-truth straggler definition: completion beyond `K_TRUE ×` the
/// job's true Pareto mean (paper §3.1 with the paper's k = 1.5).  This is
/// the *label* constant — deliberately independent of the technique's
/// (possibly swept or adapted) prediction parameter `cfg.k_straggler`, so
/// Fig. 2's k sweep scores different predictors against one fixed truth.
pub const K_TRUE: f64 = 1.5;

/// Straggler-management technique interface (Algorithm 1's hooks).
pub trait Manager {
    fn name(&self) -> &'static str;

    /// Called once per scheduling interval after arrivals + placement.
    /// Returns mitigation decisions for the engine to apply.
    fn on_interval(&mut self, w: &World, fx: &FeatureExtractor) -> Vec<Action>;

    /// A new job entered the system.
    fn on_job_arrival(&mut self, _w: &World, _fx: &FeatureExtractor, _job: JobId) {}

    /// A task (original) completed.
    fn on_task_complete(&mut self, _w: &World, _task: TaskId) {}

    /// Predicted straggler count E_S for a finished job (Eq. 14 MAPE);
    /// None if this technique does not predict.
    fn predicted_stragglers(&mut self, _job: JobId) -> Option<f64> {
        None
    }

    /// Engine pushes the adaptive straggler parameter k (paper §4.3
    /// "dynamically change the k value").
    fn set_k(&mut self, _k: f64) {}

    /// Veto hook consulted before each placement (Wrangler delays tasks
    /// headed to nodes with high straggler confidence).  Returning false
    /// leaves the task pending until a later interval.
    fn filter_placement(&mut self, _w: &World, _task: TaskId, _vm: VmId) -> bool {
        true
    }

    /// Wall-time sub-spans of the last `on_interval` call (feature
    /// extraction / model dispatch / decision logic), drained by the
    /// engine into the Predict phase profile right after the call.
    /// None when the technique does not self-instrument.
    fn take_predict_spans(&mut self) -> Option<crate::sim::trace::PredictSpans> {
        None
    }
}

/// A no-op manager (ablation floor: no straggler management).
pub struct NullManager;

impl Manager for NullManager {
    fn name(&self) -> &'static str {
        "None"
    }

    fn on_interval(&mut self, _w: &World, _fx: &FeatureExtractor) -> Vec<Action> {
        Vec::new()
    }
}

/// One simulation run.
pub struct Simulation {
    pub cfg: SimConfig,
    pub world: World,
    pub metrics: RunMetrics,
    pub fx: FeatureExtractor,
    generative: Generative,
    traces: Vec<PlanetLabTrace>,
    faults: FaultInjector,
    workload: WorkloadGenerator,
    scheduler: Box<dyn crate::scheduler::Scheduler>,
    manager: Box<dyn Manager>,
    rng: Pcg,
    interval: usize,
    /// Cooperative wall-clock deadline (coordinator cell timeout): checked
    /// between intervals, so a slow cell aborts at the next interval
    /// boundary instead of stalling its worker forever.
    deadline: Option<Instant>,
    /// Adaptive straggler parameter k (starts at cfg.k_straggler).
    pub k: f64,
    /// Rolling FP/FN window for dynamic-k adaptation.
    k_window: (u64, u64),
    /// Scratch buffer reused for per-job M_T construction.
    mt_scratch: Vec<f32>,
}

impl Simulation {
    pub fn new(
        cfg: SimConfig,
        manifest: &Manifest,
        scheduler: Box<dyn crate::scheduler::Scheduler>,
        manager: Box<dyn Manager>,
    ) -> Simulation {
        let mut rng = Pcg::new(cfg.seed, 0x51A7);
        let world = World::new(&cfg);
        let trace_params = TraceParams {
            n_intervals: cfg.n_intervals + 64,
            interval_s: cfg.interval_s,
            diurnal_amp: cfg.trace_diurnal_amp,
            noise: cfg.trace_noise,
            spike_prob: cfg.trace_spike_prob,
            ..TraceParams::default()
        };
        let mut trng = rng.fork(0x7124CE);
        let traces = (0..world.hosts.len())
            .map(|_| PlanetLabTrace::generate(&trace_params, &mut trng))
            .collect();
        // Arrival intensity: at the paper-default λ the cloudlet budget is
        // spread exactly over the horizon; a different `job_lambda` scales
        // the Poisson rate proportionally (the budget still caps totals).
        let mean_tasks = (cfg.tasks_per_job.0 + cfg.tasks_per_job.1) as f64 / 2.0;
        let budget_rate = cfg.n_workloads as f64 / (mean_tasks * cfg.n_intervals as f64);
        let lambda = (cfg.job_lambda / SimConfig::PAPER_JOB_LAMBDA) * budget_rate;
        let workload = WorkloadGenerator::new(
            rng.fork(0x3015),
            lambda,
            cfg.tasks_per_job,
            cfg.deadline_fraction,
            cfg.n_workloads,
        );
        let faults = FaultInjector::new(&cfg, rng.fork(0xFA11));
        let fx = FeatureExtractor::new(manifest);
        let generative =
            Generative::new(manifest.generative, manifest.m_feats, manifest.p_feats);
        let k = cfg.k_straggler;
        let mt_len = manifest.mt_len();
        Simulation {
            cfg,
            world,
            metrics: RunMetrics::default(),
            fx,
            generative,
            traces,
            faults,
            workload,
            scheduler,
            manager,
            rng,
            interval: 0,
            deadline: None,
            k,
            k_window: (0, 0),
            mt_scratch: vec![0.0; mt_len],
        }
    }

    /// Technique under test.
    pub fn manager_name(&self) -> &'static str {
        self.manager.name()
    }

    /// Install an event sink (sim/trace.rs §10) and record the run
    /// header.  World transitions and engine decisions are recorded from
    /// here on; retrieve with [`Simulation::run_traced`].
    pub fn set_trace(&mut self, sink: TraceSink) {
        self.world.set_trace(sink);
        let seed = self.cfg.seed;
        let n_intervals = self.cfg.n_intervals;
        let interval_s = self.cfg.interval_s;
        let technique = self.manager.name().to_string();
        let scheduler = format!("{:?}", self.cfg.scheduler);
        self.world.trace_record(|| Event::Meta {
            seed,
            n_intervals,
            interval_s,
            technique,
            scheduler,
        });
    }

    /// Run to completion; returns the metrics.
    ///
    /// Interval metrics (energy, utilization, contention) cover exactly
    /// the configured horizon (paper: 288 intervals = 24 h); the drain
    /// phase completes outstanding jobs for the response/SLA metrics but
    /// does not extend the energy window, so techniques are compared on
    /// identical wall-clock energy budgets.
    pub fn run(self) -> RunMetrics {
        self.run_traced().0
    }

    /// Like [`Simulation::run`], but also returns the event sink
    /// installed via [`Simulation::set_trace`] (callers flush file sinks
    /// with `TraceSink::finish`).
    pub fn run_traced(self) -> (RunMetrics, TraceSink) {
        let (metrics, sink, _) = self.run_traced_outcome();
        (metrics, sink)
    }

    /// Arm the cooperative wall-clock deadline: the run loop checks it
    /// before every interval (main horizon and drain) and aborts the run
    /// when exceeded.  The coordinator's per-cell timeout uses this; the
    /// granularity is one interval, which bounds how long a slow manager
    /// can overshoot.
    pub fn set_deadline(&mut self, deadline: Instant) {
        self.deadline = Some(deadline);
    }

    fn past_deadline(&self) -> bool {
        self.deadline.is_some_and(|d| Instant::now() >= d)
    }

    /// [`Simulation::run_traced`] plus a timed-out flag: `true` means the
    /// deadline armed via [`Simulation::set_deadline`] fired and the
    /// returned metrics cover only a truncated run (callers must treat
    /// them as a failure, not a result — the coordinator converts this
    /// into a per-cell error).
    pub fn run_traced_outcome(mut self) -> (RunMetrics, TraceSink, bool) {
        let n = self.cfg.n_intervals;
        let mut timed_out = false;
        for _ in 0..n {
            if self.past_deadline() {
                timed_out = true;
                break;
            }
            self.step_interval(true);
        }
        // Drain: no new arrivals, finish outstanding jobs (a 20× bounded
        // straggler on a slow share can legitimately run for hundreds of
        // intervals, so `SimConfig::drain_limit` is generous).
        let limit = self.cfg.drain_limit();
        let mut extra = 0;
        while !timed_out && self.world.has_active_jobs() && extra < limit {
            if self.past_deadline() {
                timed_out = true;
                break;
            }
            self.step_interval(false);
            extra += 1;
        }
        let sink = self.world.take_trace();
        (self.metrics, sink, timed_out)
    }

    /// Advance one scheduling interval.
    ///
    /// Each phase is wall-timed into `metrics.profile` with *contiguous*
    /// `Instant`s (each phase's end is the next phase's start), so any
    /// sum of adjacent phases equals one measurement across them — in
    /// particular predict+mitigate is exactly the old Fig. 10 lump
    /// timing around the manager block.
    pub fn step_interval(&mut self, arrivals: bool) {
        let t0 = self.interval as f64 * self.cfg.interval_s;
        let mark0 = Instant::now();
        self.advance_to(t0);
        // 1. Background (PlanetLab) load for this interval.  The setter
        //    dirties only hosts whose load actually changed.
        for (h, trace) in self.traces.iter().enumerate() {
            let load = trace.at(self.interval);
            self.world.set_background_load(HostId::new(h), load);
        }
        // 2. Release expired holds, snapshot features.
        mitigation::release_held(&mut self.world);
        self.fx.snapshot(&mut self.world);
        let mark1 = Instant::now();
        self.metrics.profile.add(Phase::Advance, mark1 - mark0);
        // 3. Job arrivals.
        if arrivals {
            let specs = self.workload.arrivals();
            for spec in specs {
                let job = self.submit_job(spec);
                self.manager.on_job_arrival(&self.world, &self.fx, job);
            }
        }
        let mark2 = Instant::now();
        self.metrics.profile.add(Phase::Arrivals, mark2 - mark1);
        // 4. Place pending tasks.
        self.place_pending();
        let mark3 = Instant::now();
        self.metrics.profile.add(Phase::Placement, mark3 - mark2);
        // 5. Straggler management (Fig. 10 overhead = predict + mitigate).
        let actions = self.manager.on_interval(&self.world, &self.fx);
        // Per-manager sub-span attribution within the Predict phase
        // (feature extract / model dispatch / decision) — additive detail,
        // excluded from the deterministic-parity contract like all timing.
        if let Some(spans) = self.manager.take_predict_spans() {
            self.metrics.profile.add_predict_spans(&spans);
        }
        let mark4 = Instant::now();
        self.metrics.profile.add(Phase::Predict, mark4 - mark3);
        self.apply_actions(actions);
        let mark5 = Instant::now();
        self.metrics.profile.add(Phase::Mitigate, mark5 - mark4);
        // 6. Metrics snapshot (main horizon only — drain intervals finish
        //    jobs but do not extend the energy/utilization window).
        if arrivals {
            self.metrics.snapshot(&self.world, self.cfg.interval_s);
            let idx = self.interval;
            let snap = self.metrics.intervals.last().unwrap().clone();
            self.world.trace_record(|| Event::Interval { index: idx, snapshot: snap });
        }
        self.metrics.profile.add(Phase::Metrics, mark5.elapsed());
        self.interval += 1;
    }

    /// Create job + tasks; sample ground-truth Pareto parameters from the
    /// generative contract at the current cluster state.
    fn submit_job(&mut self, spec: JobSpec) -> JobId {
        let jid = JobId::new(self.world.n_jobs());
        let mut tasks = Vec::with_capacity(spec.tasks.len());
        for ts in &spec.tasks {
            let tid = TaskId::new(self.world.n_tasks());
            self.world.add_task(Task {
                id: tid,
                job: jid,
                length_mi: ts.length_mi,
                demand: TaskDemand {
                    mips: ts.mips,
                    ram_gb: ts.ram_gb,
                    disk_gb: ts.disk_gb,
                    bw_kbps: ts.bw_kbps,
                },
                state: TaskState::Pending,
                vm: None,
                last_vm: None,
                remaining_mi: ts.length_mi,
                submit_t: self.world.now,
                first_start_t: None,
                restart_time: 0.0,
                restarts: 0,
                slowdown: 1.0,
                speculative_of: None,
                mitigated: false,
            });
            tasks.push(tid);
        }
        self.world.add_job(Job {
            id: jid,
            tasks,
            submit_t: self.world.now,
            deadline_driven: spec.deadline_driven,
            sla_deadline: 0.0,
            sla_weight: spec.sla_weight,
            state: JobState::Active,
            true_alpha: 2.0,
            true_beta: 1.0,
        });
        // Ground-truth (α*, β*) from current features + this job's M_T.
        let mut mt = std::mem::take(&mut self.mt_scratch);
        self.fx.build_m_t(&self.world, jid, &mut mt);
        let m_h: &[f32] = if self.world.latest_m_h.is_empty() {
            // Before the first snapshot (shouldn't happen in run()).
            &[]
        } else {
            &self.world.latest_m_h
        };
        let (alpha, beta) = if m_h.is_empty() {
            (2.0, 1.0)
        } else {
            self.generative.pareto_params(m_h, &mt)
        };
        self.mt_scratch = mt;
        self.world.set_job_ground_truth(jid, alpha, beta);
        // SLA deadline: slack × expected duration of the slowest task.
        let mean_mult = alpha * beta / (alpha - 1.0).max(0.05);
        let worst_nominal = self
            .world
            .job(jid)
            .tasks
            .iter()
            .map(|&t| {
                let task = self.world.task(t);
                task.length_mi / task.demand.mips.max(1.0)
            })
            .fold(0.0f64, f64::max);
        let deadline =
            self.world.now + self.cfg.sla_slack * worst_nominal * mean_mult + self.cfg.interval_s;
        self.world.set_job_sla_deadline(jid, deadline);
        jid
    }

    /// Place all pending tasks via the scheduler (O(pending), not
    /// O(total): the world maintains the placement queue incrementally).
    fn place_pending(&mut self) {
        // Owned snapshot (the explicit escape hatch): placement mutates the
        // pending set while walking it.
        let pending = self.world.pending().into_owned();
        for t in pending {
            if let Some(vm) = self.scheduler.pick(&self.world, t) {
                if !self.manager.filter_placement(&self.world, t, vm) {
                    let now = self.world.now;
                    self.world.trace_record(|| Event::Veto { t: now, task: t, vm });
                    continue;
                }
                let job = self.world.task(t).job;
                let slowdown = self.sample_slowdown(job);
                self.world.start_task(t, vm, slowdown);
            }
        }
    }

    /// Sample a duration multiplier from the job's ground-truth Pareto,
    /// truncated at 20× (bounded-Pareto: real response times are bounded
    /// by timeouts; also keeps the drain phase finite).
    fn sample_slowdown(&mut self, job: JobId) -> f64 {
        let j = self.world.job(job);
        self.rng.pareto(j.true_alpha, j.true_beta).min(20.0 * j.true_beta)
    }

    /// Apply manager decisions.
    fn apply_actions(&mut self, actions: Vec<Action>) {
        for a in actions {
            match a {
                Action::Speculate(t) => {
                    let job = self.world.task(t).job;
                    let slowdown = self.sample_slowdown(job);
                    let started = self.world.task(t).first_start_t;
                    let applied = mitigation::speculate(&mut self.world, t, slowdown).is_some();
                    if applied {
                        self.metrics.speculations += 1;
                        if let Some(s) = started {
                            self.metrics.mitigation_delays.push(self.world.now - s);
                        }
                    }
                    let now = self.world.now;
                    self.world.trace_record(|| Event::Mitigate {
                        t: now,
                        task: t,
                        kind: MitigationKind::Speculate,
                        applied,
                        started,
                    });
                }
                Action::Rerun(t) => {
                    let job = self.world.task(t).job;
                    let slowdown = self.sample_slowdown(job);
                    let started = self.world.task(t).first_start_t;
                    let applied =
                        mitigation::rerun(&mut self.world, t, slowdown, 30.0).is_some();
                    if applied {
                        self.metrics.reruns += 1;
                        if let Some(s) = started {
                            self.metrics.mitigation_delays.push(self.world.now - s);
                        }
                    }
                    let now = self.world.now;
                    self.world.trace_record(|| Event::Mitigate {
                        t: now,
                        task: t,
                        kind: MitigationKind::Rerun,
                        applied,
                        started,
                    });
                }
                Action::Hold(t, until) => {
                    let applied = mitigation::hold(&mut self.world, t, until);
                    let now = self.world.now;
                    self.world.trace_record(|| Event::Mitigate {
                        t: now,
                        task: t,
                        kind: MitigationKind::Hold,
                        applied,
                        started: None,
                    });
                }
            }
        }
    }

    /// Advance the world to `target`, processing completions and faults.
    fn advance_to(&mut self, target: f64) {
        loop {
            let tf = self.world.next_finish_time().unwrap_or(f64::INFINITY);
            let tfault = self.faults.next_fault_t;
            let te = tf.min(tfault).min(target);
            if te > target + 1e-9 || (self.world.now >= target - 1e-9 && te >= target) {
                // Nothing left before the target: land exactly on it.
                let done = self.world.advance(target);
                for t in done {
                    self.handle_completion(t);
                }
                return;
            }
            let done = self.world.advance(te);
            for t in done {
                self.handle_completion(t);
            }
            while let Some(f) = self.faults.poll(self.world.now) {
                self.apply_fault(f);
            }
        }
    }

    /// A task's remaining work hit zero.
    fn handle_completion(&mut self, task: TaskId) {
        if !self.world.task(task).is_running() {
            return; // killed in the same instant
        }
        let now = self.world.now;
        let host = self.world.task(task).vm.map(|v| self.world.vms[v].host);
        match self.world.task(task).speculative_of {
            Some(orig) => {
                // Clone won the race: the logical task completes now.
                self.world.complete_task(task);
                if self.world.task(orig).is_active() {
                    self.world.complete_superseded(orig);
                    self.finish_original(orig, now, host);
                }
            }
            None => {
                self.world.complete_task(task);
                if let Some(clone) = mitigation::find_clone(&self.world, task) {
                    self.world.kill_task(clone);
                }
                self.finish_original(task, now, host);
            }
        }
    }

    /// Bookkeeping when an original task's result is available.
    fn finish_original(&mut self, task: TaskId, now: f64, host: Option<HostId>) {
        let t = self.world.task(task).clone();
        self.metrics.record_task_done(&t, now);
        // Straggler ground truth: realized multiplier above the job's true
        // threshold K = k·mean (Eq. 4 semantics).
        let job = self.world.job(t.job);
        let k_thresh =
            K_TRUE * job.true_alpha * job.true_beta / (job.true_alpha - 1.0).max(0.05);
        let was_straggler = t.slowdown > k_thresh;
        if let Some(h) = host {
            self.world.note_straggler(h, was_straggler);
        }
        // Prediction scoring (Fig. 2 F1): "predicted" = the manager
        // mitigated or flagged this task.
        self.metrics.confusion.record(t.mitigated, was_straggler);
        let (job_id, mitigated) = (t.job, t.mitigated);
        self.world.trace_record(|| Event::TaskResult {
            t: now,
            task,
            job: job_id,
            mitigated,
            straggler: was_straggler,
        });
        match (t.mitigated, was_straggler) {
            (true, false) => self.k_window.0 += 1,  // false positive
            (false, true) => self.k_window.1 += 1,  // false negative
            _ => {}
        }
        self.adapt_k();
        // Scheduler reward: normalized response time.
        let nominal = (t.length_mi / t.demand.mips.max(1.0)).max(1.0);
        let response_norm = (now - t.submit_t) / nominal;
        self.scheduler.feedback(&self.world, task, response_norm);
        self.manager.on_task_complete(&self.world, task);
        // Job completion?  (per-job O(q) check, q ≤ 10)
        let jid = t.job;
        let all_done = self.world.job(jid)
            .tasks
            .iter()
            .all(|&tt| matches!(self.world.task(tt).state, TaskState::Completed { .. }));
        if all_done && self.world.job(jid).is_active() {
            self.world.finish_job(jid);
            let job = self.world.job(jid);
            let actual = job
                .tasks
                .iter()
                .filter(|&&tt| {
                    let k_th = K_TRUE * job.true_alpha * job.true_beta
                        / (job.true_alpha - 1.0).max(0.05);
                    self.world.task(tt).slowdown > k_th
                })
                .count();
            let predicted = self.manager.predicted_stragglers(jid).unwrap_or(actual as f64);
            let job = self.world.job(jid).clone();
            self.metrics.record_job_done(&job, now, predicted, actual);
            self.world.trace_record(|| Event::JobScore {
                t: now,
                job: jid,
                predicted_es: predicted,
                actual_stragglers: actual,
            });
        }
    }

    /// Dynamic k adaptation (paper §4.3): rebalance FP vs FN every 50
    /// classifications.
    fn adapt_k(&mut self) {
        if !self.cfg.dynamic_k {
            return;
        }
        let (fp, fn_) = self.k_window;
        if fp + fn_ >= 50 {
            if fp > 2 * fn_ {
                self.k = (self.k + 0.05).min(2.5);
            } else if fn_ > 2 * fp {
                self.k = (self.k - 0.05).max(1.1);
            }
            self.k_window = (0, 0);
            self.manager.set_k(self.k);
        }
    }

    /// Apply an injected fault.
    fn apply_fault(&mut self, fault: Fault) {
        match fault {
            Fault::Host { pick, intervals } => {
                let h = HostId::new(pick % self.world.hosts.len());
                let until = self.world.now + intervals as f64 * self.cfg.interval_s;
                let now = self.world.now;
                self.world.trace_record(|| Event::Fault {
                    t: now,
                    fault: FaultEvent::Host { host: h, until },
                });
                self.world.set_host_down(h, until);
                // Every task running there restarts (paper §1: node failure
                // ⇒ re-execute its tasks).  Victims are gathered with one
                // flat copy per VM task list — no per-VM Vec clones.
                let mut victims: Vec<TaskId> = Vec::new();
                for &v in &self.world.hosts[h].vms {
                    victims.extend_from_slice(&self.world.vms[v].tasks);
                }
                for t in victims {
                    self.world.reset_task(t, 30.0);
                }
                // `set_host_down` and `reset_task` self-mark the affected
                // hosts dirty — no global invalidation needed.
            }
            Fault::Cloudlet { pick } => {
                // The network fault strikes a VM; any cloudlet resident
                // there breaks down and re-runs.  Striking VMs (not a
                // uniform pick over running tasks) keeps the per-task
                // fault probability independent of how many tasks are
                // left in the system.
                let v = VmId::new(pick % self.world.vms.len());
                let victim = self.world.vms[v].tasks.first().copied();
                let now = self.world.now;
                self.world.trace_record(|| Event::Fault {
                    t: now,
                    fault: FaultEvent::Cloudlet { vm: v, task: victim },
                });
                if let Some(t) = victim {
                    self.world.reset_task(t, 30.0);
                }
            }
            Fault::VmCreation { pick } => {
                let v = VmId::new(pick % self.world.vms.len());
                let ready = self.world.now + self.cfg.interval_s;
                let now = self.world.now;
                self.world.trace_record(|| Event::Fault {
                    t: now,
                    fault: FaultEvent::VmCreation { vm: v, ready_at: ready },
                });
                self.world.set_vm_ready_at(v, ready);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predictor::features::tests::test_manifest;
    use crate::scheduler;

    fn quick_cfg() -> SimConfig {
        let mut cfg = SimConfig::test_defaults();
        cfg.n_intervals = 12;
        cfg.n_workloads = 60;
        cfg
    }

    fn run_sim(cfg: SimConfig) -> RunMetrics {
        let manifest = test_manifest();
        let sched = scheduler::build(cfg.scheduler, Pcg::seeded(cfg.seed ^ 1));
        Simulation::new(cfg, &manifest, sched, Box::new(NullManager)).run()
    }

    #[test]
    fn end_to_end_completes_all_jobs() {
        let m = run_sim(quick_cfg());
        assert!(m.jobs_done > 0, "no jobs completed");
        assert!(m.tasks_done >= 40, "only {} tasks done", m.tasks_done);
        assert!(m.avg_execution_time() > 0.0);
        assert!(m.total_energy_kwh() > 0.0);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run_sim(quick_cfg());
        let b = run_sim(quick_cfg());
        assert_eq!(a.tasks_done, b.tasks_done);
        assert!((a.avg_execution_time() - b.avg_execution_time()).abs() < 1e-9);
        assert!((a.total_energy_kwh() - b.total_energy_kwh()).abs() < 1e-12);
    }

    #[test]
    fn different_seeds_differ() {
        let mut cfg = quick_cfg();
        cfg.seed = 7;
        let a = run_sim(cfg);
        let b = run_sim(quick_cfg());
        assert!((a.avg_execution_time() - b.avg_execution_time()).abs() > 1e-9);
    }

    #[test]
    fn faults_increase_execution_time() {
        let mut calm = quick_cfg();
        calm.fault_rate = 0.0;
        calm.n_workloads = 80;
        let mut stormy = calm.clone();
        stormy.fault_rate = 4.0;
        let a = run_sim(calm);
        let b = run_sim(stormy);
        assert!(
            b.avg_execution_time() > a.avg_execution_time(),
            "faults should slow things down: {} vs {}",
            b.avg_execution_time(),
            a.avg_execution_time()
        );
        assert!(b.restart_times.iter().sum::<f64>() > 0.0);
    }

    #[test]
    fn reserved_utilization_increases_times() {
        let mut lo = quick_cfg();
        lo.fault_rate = 0.2;
        let mut hi = lo.clone();
        hi.reserved_util = 0.8;
        let a = run_sim(lo);
        let b = run_sim(hi);
        assert!(b.avg_execution_time() > a.avg_execution_time());
    }

    #[test]
    fn no_tasks_lost_or_duplicated() {
        let cfg = quick_cfg();
        let manifest = test_manifest();
        let sched = scheduler::build(cfg.scheduler, Pcg::seeded(9));
        let mut sim = Simulation::new(cfg.clone(), &manifest, sched, Box::new(NullManager));
        for _ in 0..cfg.n_intervals {
            sim.step_interval(true);
        }
        let mut extra = 0;
        // Double headroom over the engine's own drain bound: this test
        // *asserts* completion, so keep at least the seed's 600-interval
        // window rather than silently tightening it.
        let limit = 2 * sim.cfg.drain_limit();
        while sim.world.has_active_jobs() && extra < limit {
            sim.step_interval(false);
            extra += 1;
        }
        // Conservation: every original task is exactly Completed (none
        // pending/running/held), and originals completed == generated.
        let originals: Vec<&Task> =
            sim.world.debug_tasks().iter().filter(|t| t.speculative_of.is_none()).collect();
        for t in &originals {
            assert!(
                matches!(t.state, TaskState::Completed { .. }),
                "task {} stuck in {:?}",
                t.id,
                t.state
            );
        }
        assert_eq!(sim.metrics.tasks_done, originals.len());
        // Each job completed exactly once.
        assert_eq!(sim.metrics.jobs_done, sim.world.n_jobs());
        sim.world.assert_consistent();
    }

    /// Satellite: λ must actually scale arrivals — doubling `job_lambda`
    /// roughly doubles the jobs submitted over a window short enough that
    /// the cloudlet budget never clamps.
    #[test]
    fn job_lambda_scales_arrivals() {
        let jobs_submitted = |lambda: f64| {
            let mut cfg = SimConfig::test_defaults();
            cfg.scheduler = crate::config::SchedulerKind::RoundRobin;
            cfg.n_workloads = 10_000;
            cfg.n_intervals = 100;
            cfg.job_lambda = lambda;
            let manifest = test_manifest();
            let sched = scheduler::build(cfg.scheduler, Pcg::seeded(1));
            let mut sim = Simulation::new(cfg, &manifest, sched, Box::new(NullManager));
            for _ in 0..10 {
                sim.step_interval(true);
            }
            sim.world.n_jobs()
        };
        let base = jobs_submitted(SimConfig::PAPER_JOB_LAMBDA);
        let doubled = jobs_submitted(2.0 * SimConfig::PAPER_JOB_LAMBDA);
        assert!(base > 50, "baseline submitted only {base} jobs");
        let ratio = doubled as f64 / base as f64;
        assert!(
            (1.6..=2.4).contains(&ratio),
            "doubling job_lambda changed arrivals by {ratio:.2}x ({base} -> {doubled})"
        );
    }

    #[cfg(feature = "sim-trace")]
    #[test]
    fn trace_replay_matches_live_metrics() {
        let cfg = quick_cfg();
        let manifest = test_manifest();
        let sched = scheduler::build(cfg.scheduler, Pcg::seeded(cfg.seed ^ 1));
        let mut sim = Simulation::new(cfg, &manifest, sched, Box::new(NullManager));
        sim.set_trace(TraceSink::mem());
        let (m, sink) = sim.run_traced();
        assert!(!sink.is_empty());
        let replayed = crate::sim::trace::replay(sink.events());
        m.assert_deterministic_eq(&replayed, "engine-null-replay");
    }

    #[test]
    fn zero_interval_run_is_clean() {
        let mut cfg = quick_cfg();
        cfg.n_intervals = 0;
        cfg.n_workloads = 0;
        let manifest = test_manifest();
        let sched = scheduler::build(cfg.scheduler, Pcg::seeded(3));
        let mut sim = Simulation::new(cfg, &manifest, sched, Box::new(NullManager));
        sim.set_trace(TraceSink::mem());
        let (m, sink) = sim.run_traced();
        assert!(m.intervals.is_empty());
        assert_eq!(m.tasks_done, 0);
        // No phase ever ran: the profiler (and the Fig. 10 overhead it
        // defines) is exactly zero, not NaN.
        assert_eq!(m.profile.total_seconds(), 0.0);
        assert_eq!(m.manager_overhead_s(), 0.0);
        let replayed = crate::sim::trace::replay(sink.events());
        m.assert_deterministic_eq(&replayed, "zero-interval");
    }

    /// Drain-phase completions (arrivals=false intervals) must replay
    /// like any other: one-interval horizon, everything finishes during
    /// the drain.
    #[cfg(feature = "sim-trace")]
    #[test]
    fn drain_phase_only_completions_replay() {
        let mut cfg = quick_cfg();
        cfg.n_intervals = 1;
        cfg.n_workloads = 40;
        let manifest = test_manifest();
        let sched = scheduler::build(cfg.scheduler, Pcg::seeded(cfg.seed ^ 1));
        let mut sim = Simulation::new(cfg, &manifest, sched, Box::new(NullManager));
        sim.set_trace(TraceSink::mem());
        let (m, sink) = sim.run_traced();
        assert_eq!(m.intervals.len(), 1, "drain intervals must not snapshot");
        assert!(m.tasks_done > 0, "nothing completed");
        let replayed = crate::sim::trace::replay(sink.events());
        m.assert_deterministic_eq(&replayed, "drain-only");
    }

    /// An empty fleet (zero hosts/VMs) is degenerate but must not panic,
    /// NaN the interval metrics, or break replay parity.
    #[test]
    fn empty_fleet_traces_cleanly() {
        let mut cfg = quick_cfg();
        cfg.pm_counts = vec![0; cfg.pm_counts.len()];
        cfg.fault_rate = 0.0; // fault targeting needs a non-empty fleet
        cfg.n_intervals = 2;
        cfg.n_workloads = 4;
        let manifest = test_manifest();
        let sched = scheduler::build(cfg.scheduler, Pcg::seeded(5));
        let mut sim = Simulation::new(cfg, &manifest, sched, Box::new(NullManager));
        sim.set_trace(TraceSink::mem());
        let (m, sink) = sim.run_traced();
        assert_eq!(m.intervals.len(), 2);
        for iv in &m.intervals {
            assert!(iv.energy_kwh == 0.0 && iv.cpu_util == 0.0, "ghost load: {iv:?}");
            assert!(iv.contention.is_finite());
        }
        assert_eq!(m.tasks_done, 0, "nothing can run on zero VMs");
        let replayed = crate::sim::trace::replay(sink.events());
        m.assert_deterministic_eq(&replayed, "empty-fleet");
    }

    #[test]
    fn energy_within_physical_bounds() {
        let m = run_sim(quick_cfg());
        let cfg = quick_cfg();
        let w = World::new(&cfg);
        let idle_w: f64 = w.hosts.iter().map(|h| h.power_idle_w).sum();
        let peak_w: f64 = w.hosts.iter().map(|h| h.power_peak_w).sum();
        for iv in &m.intervals {
            let lo = (idle_w - 1.0) * (cfg.interval_s / 3.6e6)
                * (1.0 - iv.hosts_down as f64 / w.hosts.len() as f64);
            let hi = peak_w * cfg.interval_s / 3.6e6 + 1e-9;
            assert!(iv.energy_kwh <= hi, "energy {} above peak {}", iv.energy_kwh, hi);
            assert!(iv.energy_kwh >= lo * 0.5, "energy {} below idle floor {}", iv.energy_kwh, lo);
        }
    }
}
