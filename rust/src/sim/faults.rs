//! Weibull fault injection (paper §4.3, FIM-SIM analogue).
//!
//! Three fault classes, as in the paper's Fault Injection Module:
//! * **Host faults** — memory/processing-element faults: the host goes
//!   down for an ephemeral period (≤ `max_downtime_intervals`); every task
//!   running there must restart (paper §1/§4.3).
//! * **Cloudlet faults** — network faults: a running task breaks down and
//!   re-runs.
//! * **VM-creation faults** — a VM becomes unavailable for new placements
//!   until re-created.
//!
//! Inter-fault times follow Weibull(k = 1.5, λ = 2) (Eq. 15) scaled so the
//! fleet sees `fault_rate` faults per scheduling interval on average.

use crate::config::SimConfig;
use crate::util::rng::Pcg;

/// A fault to apply to the world.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Fault {
    /// Host index (sampled mod #hosts) down for `intervals` intervals.
    Host { pick: usize, intervals: usize },
    /// A running task (sampled mod #running) breaks and must re-run.
    Cloudlet { pick: usize },
    /// VM (sampled mod #vms) unavailable for one interval.
    VmCreation { pick: usize },
}

/// Stream of fault events in simulated time.
pub struct FaultInjector {
    rng: Pcg,
    shape: f64,
    scale: f64,
    /// Mean simulated seconds between faults.
    mean_gap_s: f64,
    max_downtime_intervals: usize,
    interval_s: f64,
    pub next_fault_t: f64,
}

impl FaultInjector {
    pub fn new(cfg: &SimConfig, mut rng: Pcg) -> FaultInjector {
        // E[Weibull(k, λ)] = λ·Γ(1 + 1/k); for k=1.5, λ=2 ⇒ ≈ 1.80549.
        let weibull_mean = cfg.fault_scale * gamma_1p(1.0 / cfg.fault_shape);
        let mean_gap_s = if cfg.fault_rate > 0.0 {
            cfg.interval_s / cfg.fault_rate
        } else {
            f64::INFINITY
        };
        let mut inj = FaultInjector {
            shape: cfg.fault_shape,
            scale: cfg.fault_scale,
            mean_gap_s: mean_gap_s / weibull_mean,
            max_downtime_intervals: cfg.max_downtime_intervals.max(1),
            interval_s: cfg.interval_s,
            next_fault_t: 0.0,
            rng: rng.fork(0xFA017),
        };
        inj.next_fault_t = inj.draw_gap();
        inj
    }

    fn draw_gap(&mut self) -> f64 {
        if self.mean_gap_s.is_infinite() {
            f64::INFINITY
        } else {
            self.rng.weibull(self.shape, self.scale) * self.mean_gap_s
        }
    }

    /// Downtime duration for a host fault, in seconds (1..=max intervals).
    pub fn draw_downtime_s(&mut self) -> f64 {
        self.rng.int_range(1, self.max_downtime_intervals as i64) as f64 * self.interval_s
    }

    /// If a fault fires at or before `now`, return it and schedule the next.
    pub fn poll(&mut self, now: f64) -> Option<Fault> {
        if now + 1e-9 < self.next_fault_t {
            return None;
        }
        let gap = self.draw_gap();
        self.next_fault_t += gap;
        let intervals = self.rng.int_range(1, self.max_downtime_intervals as i64) as usize;
        let roll = self.rng.f64();
        let pick = self.rng.next_u64() as usize;
        Some(if roll < 0.3 {
            Fault::Host { pick, intervals }
        } else if roll < 0.8 {
            Fault::Cloudlet { pick }
        } else {
            Fault::VmCreation { pick }
        })
    }
}

/// Γ(1 + x) for x in (0, 1] via Lanczos (sufficient accuracy for scaling).
fn gamma_1p(x: f64) -> f64 {
    // Γ(1+x) = x·Γ(x); use Lanczos approximation for Γ.
    const G: f64 = 7.0;
    const C: [f64; 9] = [
        0.99999999999980993,
        676.5203681218851,
        -1259.1392167224028,
        771.32342877765313,
        -176.61502916214059,
        12.507343278686905,
        -0.13857109526572012,
        9.9843695780195716e-6,
        1.5056327351493116e-7,
    ];
    let z = x; // compute Γ(z+1)
    let mut acc = C[0];
    for (i, &c) in C.iter().enumerate().skip(1) {
        acc += c / (z + i as f64);
    }
    let t = z + G + 0.5;
    (2.0 * std::f64::consts::PI).sqrt() * t.powf(z + 0.5) * (-t).exp() * acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;

    #[test]
    fn gamma_known_values() {
        assert!((gamma_1p(1.0) - 1.0).abs() < 1e-9); // Γ(2) = 1
        assert!((gamma_1p(0.5) - 0.8862269254).abs() < 1e-6); // Γ(1.5)
        assert!((gamma_1p(1.0 / 1.5) - 0.9027452929).abs() < 1e-6); // Γ(5/3)
    }

    #[test]
    fn fault_rate_calibrated() {
        let mut cfg = SimConfig::test_defaults();
        cfg.fault_rate = 0.5;
        let mut inj = FaultInjector::new(&cfg, Pcg::seeded(1));
        let horizon = 4000.0 * cfg.interval_s;
        let mut count = 0;
        let mut t = 0.0;
        while t < horizon {
            t = inj.next_fault_t.min(horizon);
            if t >= horizon {
                break;
            }
            inj.poll(t).unwrap();
            count += 1;
        }
        let per_interval = count as f64 / 4000.0;
        assert!((per_interval - 0.5).abs() < 0.05, "rate {per_interval}");
    }

    #[test]
    fn zero_rate_never_fires() {
        let mut cfg = SimConfig::test_defaults();
        cfg.fault_rate = 0.0;
        let mut inj = FaultInjector::new(&cfg, Pcg::seeded(2));
        assert!(inj.poll(1e12).is_none());
    }

    #[test]
    fn fault_mix_roughly_30_50_20() {
        let mut cfg = SimConfig::test_defaults();
        cfg.fault_rate = 1.0;
        let mut inj = FaultInjector::new(&cfg, Pcg::seeded(3));
        let (mut h, mut c, mut v) = (0, 0, 0);
        let mut t: f64;
        for _ in 0..5000 {
            t = inj.next_fault_t;
            match inj.poll(t).unwrap() {
                Fault::Host { .. } => h += 1,
                Fault::Cloudlet { .. } => c += 1,
                Fault::VmCreation { .. } => v += 1,
            }
        }
        let total = (h + c + v) as f64;
        assert!((h as f64 / total - 0.3).abs() < 0.03);
        assert!((c as f64 / total - 0.5).abs() < 0.03);
        assert!((v as f64 / total - 0.2).abs() < 0.03);
    }

    #[test]
    fn downtime_bounded() {
        let cfg = SimConfig::test_defaults();
        let mut inj = FaultInjector::new(&cfg, Pcg::seeded(4));
        for _ in 0..200 {
            let d = inj.draw_downtime_s();
            assert!(d >= cfg.interval_s - 1e-9);
            assert!(d <= cfg.max_downtime_intervals as f64 * cfg.interval_s + 1e-9);
        }
    }
}
