//! Configuration system: simulation parameters from Tables 3–4 of the
//! paper, overridable from JSON config files (`configs/*.json`) and CLI
//! flags.  Every experiment in `experiments/` starts from
//! `SimConfig::paper_defaults()` and tweaks the swept parameter only.

use crate::util::cli::Args;
use crate::util::json::{self, Json};
use anyhow::{Context, Result};
use std::path::Path;

/// One physical-machine type (Table 3).
#[derive(Clone, Debug, PartialEq)]
pub struct PmType {
    pub name: String,
    /// Per-core MIPS (paper: CPU IPS 2000 million, scaled by clock).
    pub mips_per_core: f64,
    pub cores: usize,
    pub ram_gb: f64,
    pub disk_gb: f64,
    /// VMs hosted per PM of this type (Table 3 "Number of Virtual Nodes").
    pub vms_per_pm: usize,
    /// Idle / peak power draw in watts (Table 4 ranges, SPEC-style).
    pub power_idle_w: f64,
    pub power_peak_w: f64,
    /// Cost in C$ per interval (Table 4: workload cost 3–5 C$).
    pub cost_per_interval: f64,
    /// Network bandwidth per host in KB/s (Table 4: 1–2 KB/s).
    pub bw_kbps: f64,
}

/// Straggler-management technique selector (paper §4.6 + START).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Technique {
    Start,
    IgruSd,
    Wrangler,
    Grass,
    Dolly,
    Sgc,
    NearestFit,
    /// LATE (Table 1 extra baseline).
    Late,
    /// RPPS (ARIMA; compared on prediction accuracy in Fig. 9).
    Rpps,
    /// No straggler management at all (ablation floor).
    None,
}

impl Technique {
    pub fn parse(s: &str) -> Result<Technique> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "start" => Technique::Start,
            "igru-sd" | "igru_sd" | "igru" => Technique::IgruSd,
            "wrangler" => Technique::Wrangler,
            "grass" => Technique::Grass,
            "dolly" => Technique::Dolly,
            "sgc" => Technique::Sgc,
            "nearestfit" | "nearest-fit" => Technique::NearestFit,
            "late" => Technique::Late,
            "rpps" => Technique::Rpps,
            "none" => Technique::None,
            other => anyhow::bail!("unknown technique {other:?}"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Technique::Start => "START",
            Technique::IgruSd => "IGRU-SD",
            Technique::Wrangler => "Wrangler",
            Technique::Grass => "GRASS",
            Technique::Dolly => "Dolly",
            Technique::Sgc => "SGC",
            Technique::NearestFit => "NearestFit",
            Technique::Late => "LATE",
            Technique::Rpps => "RPPS",
            Technique::None => "None",
        }
    }

    /// All techniques compared in the paper's figures, in plot order.
    pub fn paper_set() -> Vec<Technique> {
        vec![
            Technique::Start,
            Technique::IgruSd,
            Technique::Sgc,
            Technique::Wrangler,
            Technique::Grass,
            Technique::Dolly,
            Technique::NearestFit,
        ]
    }
}

/// Scheduling policy underneath every technique (paper §4.5).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedulerKind {
    /// A3C-R2N2 surrogate: online actor-critic over host/task features.
    A3c,
    /// Uniform random placement (used to generate diverse training data).
    Random,
    RoundRobin,
    /// Min-min heuristic (classic cloud baseline).
    MinMin,
}

impl SchedulerKind {
    pub fn parse(s: &str) -> Result<SchedulerKind> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "a3c" | "a3c-r2n2" => SchedulerKind::A3c,
            "random" => SchedulerKind::Random,
            "roundrobin" | "round-robin" | "rr" => SchedulerKind::RoundRobin,
            "minmin" | "min-min" => SchedulerKind::MinMin,
            other => anyhow::bail!("unknown scheduler {other:?}"),
        })
    }
}

/// Full simulation configuration (defaults = paper Tables 3–4).
#[derive(Clone, Debug)]
pub struct SimConfig {
    pub seed: u64,
    /// PM counts per type in `pm_types` order.
    pub pm_counts: Vec<usize>,
    pub pm_types: Vec<PmType>,
    /// Total workloads (cloudlets) to generate (Table 4: 5000).
    pub n_workloads: usize,
    /// Scheduling-interval length in seconds (PlanetLab: 300 s).
    pub interval_s: f64,
    /// Number of scheduling intervals to simulate (paper: 288 = 24 h).
    pub n_intervals: usize,
    /// Poisson job-arrival intensity (paper §4.2: λ = 1.2).  The engine
    /// spreads the `n_workloads` cloudlet budget over the horizon at the
    /// paper default; raising/lowering λ proportionally speeds up/slows
    /// down arrivals (the budget still caps the total).
    pub job_lambda: f64,
    /// Tasks per job: uniform in [min, max] (paper: 2..10).
    pub tasks_per_job: (usize, usize),
    /// Fraction of jobs that are deadline-driven (paper: 0.5).
    pub deadline_fraction: f64,
    /// Reserved (blocked) utilization fraction, the Fig. 6/8 sweep knob.
    pub reserved_util: f64,
    /// Straggler parameter k (paper: 1.5, dynamically adapted).
    pub k_straggler: f64,
    /// START inference cadence in intervals (Fig. 2's I sweep; 1 = every
    /// interval).
    pub predict_every: usize,
    /// START history window length in steps (Fig. 2's T sweep; 0 = the
    /// full rollout window baked into the artifact).
    pub window_steps: usize,
    /// Adapt k online from observed false-positive/negative balance.
    pub dynamic_k: bool,
    /// Weibull fault model (Eq. 15): shape, scale (paper: 1.5, 2).
    pub fault_shape: f64,
    pub fault_scale: f64,
    /// Mean faults injected per interval across the fleet.
    pub fault_rate: f64,
    /// Max host downtime, in intervals (paper: ephemeral, ≤ 4).
    pub max_downtime_intervals: usize,
    /// Technique under test.
    pub technique: Technique,
    pub scheduler: SchedulerKind,
    /// SLA deadline slack: deadline = submit + slack · expected duration.
    pub sla_slack: f64,
    /// Speculation/rerun mitigation wait bound M_time, in seconds.
    pub m_time_s: f64,
    /// Workload trace shape (PlanetLab-like synthetic generator).
    pub trace_diurnal_amp: f64,
    pub trace_noise: f64,
    pub trace_spike_prob: f64,
    /// Debug/parity knob: route every `World` query through the seed
    /// engine's O(total) full scans instead of the incremental indexes.
    /// Used by the golden-parity test and the `scale` benchmark baseline;
    /// never enabled for real experiments (see DESIGN.md §3).
    pub reference_scans: bool,
}

impl SimConfig {
    /// The paper's default arrival intensity (§4.2).  `job_lambda` scales
    /// arrivals relative to this baseline.
    pub const PAPER_JOB_LAMBDA: f64 = 1.2;

    /// Floor on the drain-phase bound so tiny runs still get a generous
    /// window for bounded 20× stragglers to finish.
    pub const MIN_DRAIN_INTERVALS: usize = 400;

    /// Maximum extra intervals the engine (and its tests) may spend
    /// draining outstanding jobs after the measured horizon.
    pub fn drain_limit(&self) -> usize {
        (4 * self.n_intervals).max(Self::MIN_DRAIN_INTERVALS)
    }

    /// Paper defaults (Tables 3–4, §4).
    pub fn paper_defaults() -> SimConfig {
        SimConfig {
            seed: 42,
            // 25×12 + 14×6 + 8×2 = 400 VMs (Table 4).
            pm_counts: vec![25, 14, 8],
            pm_types: vec![
                PmType {
                    name: "Core2Duo-2.4GHz".into(),
                    mips_per_core: 2000.0 * 2.4 / 2.2,
                    cores: 2,
                    ram_gb: 6.0,
                    disk_gb: 320.0,
                    vms_per_pm: 12,
                    power_idle_w: 108.0,
                    power_peak_w: 273.0,
                    cost_per_interval: 3.0,
                    bw_kbps: 1.5,
                },
                PmType {
                    name: "i5-2310-2.9GHz".into(),
                    mips_per_core: 2000.0 * 2.9 / 2.2,
                    cores: 4,
                    ram_gb: 4.0,
                    disk_gb: 160.0,
                    vms_per_pm: 6,
                    power_idle_w: 120.0,
                    power_peak_w: 250.0,
                    cost_per_interval: 4.0,
                    bw_kbps: 2.0,
                },
                PmType {
                    name: "XeonE5-2407-2.2GHz".into(),
                    mips_per_core: 2000.0,
                    cores: 4,
                    ram_gb: 2.0,
                    disk_gb: 160.0,
                    vms_per_pm: 2,
                    power_idle_w: 130.0,
                    power_peak_w: 240.0,
                    cost_per_interval: 5.0,
                    bw_kbps: 2.0,
                },
            ],
            n_workloads: 5000,
            interval_s: 300.0,
            n_intervals: 288,
            job_lambda: 1.2,
            tasks_per_job: (2, 10),
            deadline_fraction: 0.5,
            reserved_util: 0.0,
            k_straggler: 1.5,
            predict_every: 1,
            window_steps: 0,
            dynamic_k: true,
            fault_shape: 1.5,
            fault_scale: 2.0,
            fault_rate: 0.6,
            max_downtime_intervals: 4,
            technique: Technique::Start,
            scheduler: SchedulerKind::A3c,
            sla_slack: 2.0,
            m_time_s: 600.0,
            trace_diurnal_amp: 0.25,
            trace_noise: 0.08,
            trace_spike_prob: 0.02,
            reference_scans: false,
        }
    }

    /// Smaller configuration for fast tests / CI.
    pub fn test_defaults() -> SimConfig {
        let mut c = Self::paper_defaults();
        c.pm_counts = vec![4, 3, 2];
        c.n_workloads = 300;
        c.n_intervals = 24;
        c
    }

    /// Total VM count implied by the PM fleet.
    pub fn total_vms(&self) -> usize {
        self.pm_counts
            .iter()
            .zip(&self.pm_types)
            .map(|(&n, t)| n * t.vms_per_pm)
            .sum()
    }

    /// Total PM count.
    pub fn total_pms(&self) -> usize {
        self.pm_counts.iter().sum()
    }

    /// Apply overrides from a parsed JSON object (unknown keys rejected).
    pub fn apply_json(&mut self, v: &Json) -> Result<()> {
        let obj = v.as_obj().context("config root must be an object")?;
        for (key, val) in obj {
            match key.as_str() {
                "seed" => self.seed = val.as_f64().context("seed")? as u64,
                "pm_counts" => {
                    self.pm_counts = val
                        .as_arr()
                        .context("pm_counts")?
                        .iter()
                        .map(|x| x.as_usize().context("pm_counts entry"))
                        .collect::<Result<_>>()?
                }
                "n_workloads" => self.n_workloads = val.as_usize().context("n_workloads")?,
                "interval_s" => self.interval_s = val.as_f64().context("interval_s")?,
                "n_intervals" => self.n_intervals = val.as_usize().context("n_intervals")?,
                "job_lambda" => self.job_lambda = val.as_f64().context("job_lambda")?,
                "deadline_fraction" => {
                    self.deadline_fraction = val.as_f64().context("deadline_fraction")?
                }
                "reserved_util" => self.reserved_util = val.as_f64().context("reserved_util")?,
                "k_straggler" => self.k_straggler = val.as_f64().context("k_straggler")?,
                "predict_every" => self.predict_every = val.as_usize().context("predict_every")?,
                "window_steps" => self.window_steps = val.as_usize().context("window_steps")?,
                "dynamic_k" => self.dynamic_k = val.as_bool().context("dynamic_k")?,
                "fault_rate" => self.fault_rate = val.as_f64().context("fault_rate")?,
                "fault_shape" => self.fault_shape = val.as_f64().context("fault_shape")?,
                "fault_scale" => self.fault_scale = val.as_f64().context("fault_scale")?,
                "max_downtime_intervals" => {
                    self.max_downtime_intervals = val.as_usize().context("max_downtime")?
                }
                "technique" => {
                    self.technique = Technique::parse(val.as_str().context("technique")?)?
                }
                "scheduler" => {
                    self.scheduler = SchedulerKind::parse(val.as_str().context("scheduler")?)?
                }
                "sla_slack" => self.sla_slack = val.as_f64().context("sla_slack")?,
                "m_time_s" => self.m_time_s = val.as_f64().context("m_time_s")?,
                "reference_scans" => {
                    self.reference_scans = val.as_bool().context("reference_scans")?
                }
                other => anyhow::bail!("unknown config key {other:?}"),
            }
        }
        Ok(())
    }

    /// Load overrides from a JSON file.
    pub fn apply_file(&mut self, path: impl AsRef<Path>) -> Result<()> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        self.apply_json(&json::parse(&text)?)
    }

    /// Apply CLI overrides (flags shared by all subcommands).
    pub fn apply_cli(&mut self, args: &Args) -> Result<()> {
        if let Some(path) = args.opt_str("config") {
            self.apply_file(path)?;
        }
        self.seed = args.u64_or("seed", self.seed)?;
        self.n_workloads = args.usize_or("workloads", self.n_workloads)?;
        self.n_intervals = args.usize_or("intervals", self.n_intervals)?;
        self.reserved_util = args.f64_or("reserved-util", self.reserved_util)?;
        self.k_straggler = args.f64_or("k", self.k_straggler)?;
        self.fault_rate = args.f64_or("fault-rate", self.fault_rate)?;
        if let Some(t) = args.opt_str("technique") {
            self.technique = Technique::parse(t)?;
        }
        if let Some(s) = args.opt_str("scheduler") {
            self.scheduler = SchedulerKind::parse(s)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_defaults_match_table4() {
        let c = SimConfig::paper_defaults();
        assert_eq!(c.total_vms(), 400);
        assert_eq!(c.n_workloads, 5000);
        assert_eq!(c.n_intervals, 288);
        assert_eq!(c.job_lambda, 1.2);
        assert_eq!(c.k_straggler, 1.5);
        assert_eq!(c.fault_shape, 1.5);
        assert_eq!(c.fault_scale, 2.0);
        assert_eq!(c.pm_types.len(), 3);
    }

    #[test]
    fn json_overrides() {
        let mut c = SimConfig::paper_defaults();
        let v = json::parse(
            r#"{"seed": 7, "n_workloads": 100, "technique": "dolly",
                "pm_counts": [1, 1, 1], "reserved_util": 0.4}"#,
        )
        .unwrap();
        c.apply_json(&v).unwrap();
        assert_eq!(c.seed, 7);
        assert_eq!(c.n_workloads, 100);
        assert_eq!(c.technique, Technique::Dolly);
        assert_eq!(c.total_vms(), 12 + 6 + 2);
        assert_eq!(c.reserved_util, 0.4);
    }

    #[test]
    fn unknown_key_rejected() {
        let mut c = SimConfig::paper_defaults();
        let v = json::parse(r#"{"n_worloads": 5}"#).unwrap();
        assert!(c.apply_json(&v).is_err());
    }

    #[test]
    fn drain_limit_unifies_bounds() {
        let mut c = SimConfig::paper_defaults();
        assert_eq!(c.drain_limit(), 4 * 288);
        c.n_intervals = 12;
        assert_eq!(c.drain_limit(), SimConfig::MIN_DRAIN_INTERVALS);
    }

    #[test]
    fn technique_parse_roundtrip() {
        for t in Technique::paper_set() {
            assert_eq!(Technique::parse(t.name()).unwrap(), t);
        }
        assert!(Technique::parse("quantum").is_err());
    }
}
