//! The START straggler manager — Algorithm 1 of the paper.
//!
//! Per interval, for every active job: run the Encoder-LSTM rollout (via
//! the batched AOT artifact, up to 8 jobs per PJRT dispatch) to get
//! (α, β), compute E_S = q·(K/β)^(−α) (Eq. 4), and once the job has only
//! ⌊E_S⌋ tasks left, mitigate the remainder — **speculation** for
//! deadline-driven jobs, **re-run** otherwise (§3.3).  The target node is
//! chosen by the mitigation engine (lowest straggler moving average).

use crate::mitigation::Action;
use crate::predictor::{FeatureExtractor, StartPredictor};
use crate::sim::engine::Manager;
use crate::sim::trace::PredictSpans;
use crate::sim::types::*;
use crate::sim::world::World;
use std::collections::HashMap;
use std::time::Instant;

pub struct StartManager {
    predictor: StartPredictor,
    /// Predict every this many intervals (Fig. 2's I sweep).
    pub predict_every: usize,
    /// Predict only during a job's first `window_ticks` intervals (Alg. 1
    /// lines 6–13: the (α, β) estimate is produced over the T-window after
    /// submission, then the job runs to its mitigation point).
    pub window_ticks: usize,
    tick: usize,
    /// Per-job age in intervals.
    ages: HashMap<JobId, usize>,
    /// Latest prediction per job: (α, β, E_S).
    predictions: HashMap<JobId, (f64, f64, f64)>,
    /// Kept after completion for MAPE scoring.
    final_predictions: HashMap<JobId, f64>,
    /// Sub-span breakdown of the last `on_interval` (drained by the engine
    /// into `PhaseProfile` after each interval).
    spans: Option<PredictSpans>,
}

impl StartManager {
    pub fn new(predictor: StartPredictor) -> Self {
        Self {
            predictor,
            predict_every: 1,
            window_ticks: 5,
            tick: 0,
            ages: HashMap::new(),
            predictions: HashMap::new(),
            final_predictions: HashMap::new(),
            spans: None,
        }
    }

    /// Latest (α, β, E_S) for a job, if predicted.
    pub fn prediction(&self, job: JobId) -> Option<(f64, f64, f64)> {
        self.predictions.get(&job).copied()
    }
}

impl Manager for StartManager {
    fn name(&self) -> &'static str {
        "START"
    }

    fn set_k(&mut self, k: f64) {
        self.predictor.k = k;
    }

    fn on_interval(&mut self, w: &World, fx: &FeatureExtractor) -> Vec<Action> {
        // 1. Refresh predictions, batched over the rollout_batch lanes
        //    (every `predict_every` intervals — the paper's I parameter).
        // Borrowed view over the registry's sorted active-job set — no
        // per-interval Vec (the old signature cloned it every tick).
        let active = w.active_jobs();
        let do_predict = self.tick % self.predict_every.max(1) == 0;
        self.tick += 1;
        // Per-job B=1 rollouts: on the CPU PJRT backend the batched (B=8)
        // artifact costs ~141 µs/job vs ~82 µs for B=1 (batching pays
        // only when a wide MXU would otherwise idle) — DESIGN.md §7.
        // predict_batch remains available for accelerator builds.
        if do_predict {
            for &job in active.iter() {
                let age = self.ages.entry(job).or_insert(0);
                *age += 1;
                if *age > self.window_ticks {
                    continue; // Alg. 1: predict over the first T window only
                }
                match self.predictor.predict(w, fx, job) {
                    Ok(p) => {
                        self.predictions.insert(p.job, (p.alpha, p.beta, p.expected));
                        self.final_predictions.insert(p.job, p.expected);
                    }
                    Err(_) => continue,
                }
            }
        }
        // 2. Mitigation triggers.  Two prediction-driven conditions:
        //    (a) Alg. 1's end-game: only ⌊E_S⌉ active tasks remain — the
        //        stragglers holding the job open;
        //    (b) per-task threshold: a task's elapsed execution already
        //        exceeds the *predicted* straggler threshold
        //        K̂ = k·α̂β̂/(α̂−1) in multiplier units (elapsed / nominal).
        //        This is the paper's "predict which tasks might be
        //        stragglers" applied at task granularity and is what
        //        "nearly eliminates the detection time" (Fig. 5).
        //    Condition (b) alone would mis-fire on tasks slowed purely by
        //    queueing; (a) alone fires too late and too bluntly — together
        //    they give early + precise mitigation.
        let decide_start = Instant::now();
        let mut actions = Vec::new();
        for &job in active.iter() {
            let Some(&(alpha, beta, es)) = self.predictions.get(&job) else { continue };
            let es_round = es.round() as usize;
            let q = w.job(job).tasks.len();
            let done = w.completed_tasks(job);
            let endgame = es_round > 0 && done + es_round >= q;
            let k_hat = self.predictor.k * alpha * beta / (alpha - 1.0).max(0.05);
            for &t in &w.job(job).tasks {
                let task = w.task(t);
                if !task.is_running() || task.speculative_of.is_some() || task.mitigated {
                    continue;
                }
                let nominal = task.length_mi / task.demand.mips.max(1.0);
                let elapsed_mult = task
                    .first_start_t
                    .map(|s| (w.now - s) / nominal.max(1.0))
                    .unwrap_or(0.0);
                // Projected final multiplier from observed progress: a task
                // 10 % done after 1.5 nominal durations projects to 15× —
                // predicted straggler long before it *becomes* one.
                let progress = task.progress();
                let projected = if progress > 0.02 {
                    elapsed_mult / progress
                } else if elapsed_mult > 0.5 {
                    f64::INFINITY
                } else {
                    0.0
                };
                let predicted_straggler =
                    elapsed_mult > k_hat || (elapsed_mult > 0.25 * k_hat && projected > k_hat);
                if !(endgame || predicted_straggler) {
                    continue;
                }
                // Deadline-driven ⇒ speculate (fastest result); otherwise
                // re-run — but never discard a nearly-finished execution.
                actions.push(if w.job(job).deadline_driven || task.progress() > 0.5 {
                    Action::Speculate(t)
                } else {
                    Action::Rerun(t)
                });
            }
        }
        let (features, dispatch) = self.predictor.take_spans();
        self.spans = Some(PredictSpans { features, dispatch, decide: decide_start.elapsed() });
        actions
    }

    fn take_predict_spans(&mut self) -> Option<PredictSpans> {
        self.spans.take()
    }

    fn on_task_complete(&mut self, w: &World, task: TaskId) {
        let job = w.task(task).job;
        // The engine flips the job to Done only after this callback, so
        // also treat "no active tasks left" (registry counter) as job end
        // — otherwise this cleanup never fires and per-job state leaks
        // for the whole run.
        if !w.job(job).is_active() || w.job_active_count(job) == 0 {
            self.predictions.remove(&job);
            self.ages.remove(&job);
        }
    }

    fn predicted_stragglers(&mut self, job: JobId) -> Option<f64> {
        self.final_predictions.remove(&job)
    }
}

