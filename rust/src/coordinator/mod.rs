//! The START coordinator (L3 leader): wires the AOT models, scheduler,
//! technique manager and simulator together; runs experiment cells on a
//! worker-thread pool (one PJRT client per worker — executables are not
//! shared across threads).

pub mod start_manager;

pub use start_manager::StartManager;

use crate::baselines::*;
use crate::config::{SimConfig, Technique};
use crate::predictor::{IgruPredictor, StartPredictor};
use crate::runtime::{IgruModel, Manifest, PjrtRuntime, StartModel};

use crate::sim::engine::{Manager, NullManager, Simulation};
use crate::sim::metrics::RunMetrics;
use crate::util::rng::Pcg;
use anyhow::{Context, Result};
use std::path::PathBuf;
use std::rc::Rc;
use std::sync::mpsc;
use std::sync::Arc;

/// Per-worker model bundle (PJRT client + compiled executables).
pub struct Models {
    pub runtime: PjrtRuntime,
    pub manifest: Manifest,
    /// Compiled executables are shared (Rc) across every manager built on
    /// this worker — re-parsing + re-compiling the 1.1 MB HLO text per
    /// experiment cell cost ~1 s/cell before this (DESIGN.md §7).
    pub start: Rc<StartModel>,
    pub igru: Rc<IgruModel>,
}

impl Models {
    /// Load everything from an artifact directory.
    pub fn load(art_dir: impl Into<PathBuf>) -> Result<Models> {
        let dir = art_dir.into();
        let manifest = Manifest::load(&dir).context("loading manifest")?;
        let runtime = PjrtRuntime::new(&dir)?;
        let start = Rc::new(StartModel::load(&runtime, &manifest)?);
        let igru = Rc::new(IgruModel::load(&runtime, &manifest)?);
        Ok(Models { runtime, manifest, start, igru })
    }

    /// Load from the default artifact location.
    pub fn load_default() -> Result<Models> {
        Self::load(crate::find_artifact_dir())
    }
}

/// Instantiate the manager for a technique.
///
/// Prediction-based techniques (START, IGRU-SD) consume the AOT models;
/// the reactive baselines are model-free.
pub fn build_manager(technique: Technique, models: &Models, cfg: &SimConfig) -> Result<Box<dyn Manager>> {
    Ok(match technique {
        Technique::Start => {
            let mut predictor = StartPredictor::new(Rc::clone(&models.start), cfg.k_straggler);
            if cfg.window_steps > 0 {
                predictor.window_steps = cfg.window_steps;
            }
            let mut mgr = StartManager::new(predictor);
            mgr.predict_every = cfg.predict_every.max(1);
            Box::new(mgr)
        }
        Technique::IgruSd => {
            Box::new(IgruSdManager::new(IgruPredictor::new(Rc::clone(&models.igru), 1.15)))
        }
        Technique::Wrangler => Box::new(WranglerManager::new()),
        Technique::Grass => Box::new(GrassManager::new()),
        Technique::Dolly => Box::new(DollyManager::new()),
        Technique::Sgc => Box::new(SgcManager::new()),
        Technique::NearestFit => Box::new(NearestFitManager::new()),
        Technique::Late => Box::new(LateManager::new()),
        Technique::Rpps => Box::new(RppsManager::new()),
        Technique::None => Box::new(NullManager),
    })
}

/// Run one simulation cell (one technique, one config) end to end.
pub fn run_one(cfg: &SimConfig, models: &Models) -> Result<RunMetrics> {
    let scheduler = crate::scheduler::build(cfg.scheduler, Pcg::new(cfg.seed, 0x5C8E));
    let manager = build_manager(cfg.technique, models, cfg)?;
    let sim = Simulation::new(cfg.clone(), &models.manifest, scheduler, manager);
    Ok(sim.run())
}

/// A labelled experiment cell.
#[derive(Clone)]
pub struct Cell {
    pub label: String,
    pub cfg: SimConfig,
}

/// Run cells on a worker pool.  Each worker owns its own PJRT client (the
/// leader/worker topology: the leader distributes cells over an mpsc
/// queue and collects `(label, metrics)` results).
pub fn run_many(cells: Vec<Cell>, threads: usize, art_dir: PathBuf) -> Result<Vec<(String, RunMetrics)>> {
    let threads = threads.max(1).min(cells.len().max(1));
    let (work_tx, work_rx) = mpsc::channel::<Cell>();
    let work_rx = Arc::new(std::sync::Mutex::new(work_rx));
    let (res_tx, res_rx) = mpsc::channel::<Result<(String, RunMetrics)>>();
    let n_cells = cells.len();
    for cell in cells {
        work_tx.send(cell).unwrap();
    }
    drop(work_tx);
    let mut handles = Vec::new();
    for _ in 0..threads {
        let rx = Arc::clone(&work_rx);
        let tx = res_tx.clone();
        let dir = art_dir.clone();
        handles.push(std::thread::spawn(move || {
            let models = match Models::load(dir) {
                Ok(m) => m,
                Err(e) => {
                    let _ = tx.send(Err(e));
                    return;
                }
            };
            loop {
                let cell = { rx.lock().unwrap().recv() };
                let Ok(cell) = cell else { break };
                let result = run_one(&cell.cfg, &models).map(|m| (cell.label, m));
                if tx.send(result).is_err() {
                    break;
                }
            }
        }));
    }
    drop(res_tx);
    let mut out = Vec::with_capacity(n_cells);
    for r in res_rx {
        out.push(r?);
    }
    for h in handles {
        let _ = h.join();
    }
    Ok(out)
}
