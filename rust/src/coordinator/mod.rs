//! The START coordinator (L3 leader): wires the AOT models, scheduler,
//! technique manager and simulator together; runs experiment cells on a
//! worker-thread pool (one PJRT client per worker — executables are not
//! shared across threads).
//!
//! The batch runner is fault-tolerant and resumable (DESIGN.md §12):
//! worker panics are isolated to the failing cell, transient failures are
//! retried with deterministic capped backoff, a per-cell wall-clock
//! deadline (plus a leader-side watchdog) bounds hung cells, and a
//! crash-safe fsync'd results journal lets an interrupted paper-scale
//! batch resume by skipping completed cells — bit-identical to an
//! uninterrupted run.

pub mod journal;
pub mod start_manager;

pub use start_manager::StartManager;

use crate::baselines::*;
use crate::config::{SimConfig, Technique};
use crate::predictor::{IgruPredictor, StartPredictor};
use crate::runtime::{IgruModel, Manifest, PjrtRuntime, StartModel};

use crate::sim::engine::{Manager, NullManager, Simulation};
use crate::sim::metrics::RunMetrics;
use crate::sim::trace::TraceSink;
use crate::util::rng::Pcg;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::{HashMap, HashSet};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::rc::Rc;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// Per-worker model bundle (PJRT client + compiled executables).
pub struct Models {
    pub runtime: PjrtRuntime,
    pub manifest: Manifest,
    /// Compiled executables are shared (Rc) across every manager built on
    /// this worker — re-parsing + re-compiling the 1.1 MB HLO text per
    /// experiment cell cost ~1 s/cell before this (DESIGN.md §7).
    pub start: Rc<StartModel>,
    pub igru: Rc<IgruModel>,
}

impl Models {
    /// Load everything from an artifact directory.
    pub fn load(art_dir: impl Into<PathBuf>) -> Result<Models> {
        let dir = art_dir.into();
        let manifest = Manifest::load(&dir).context("loading manifest")?;
        let runtime = PjrtRuntime::new(&dir)?;
        let start = Rc::new(StartModel::load(&runtime, &manifest)?);
        let igru = Rc::new(IgruModel::load(&runtime, &manifest)?);
        Ok(Models { runtime, manifest, start, igru })
    }

    /// Load from the default artifact location.
    pub fn load_default() -> Result<Models> {
        Self::load(crate::find_artifact_dir())
    }
}

/// Instantiate a manager that needs no AOT models (the reactive
/// baselines); `None` for the prediction-based techniques (START,
/// IGRU-SD).  Shared by [`build_manager`], the hermetic run path, and
/// the parity/replay test suites.
pub fn model_free_manager(technique: Technique) -> Option<Box<dyn Manager>> {
    Some(match technique {
        Technique::Start | Technique::IgruSd => return None,
        Technique::Wrangler => Box::new(WranglerManager::new()),
        Technique::Grass => Box::new(GrassManager::new()),
        Technique::Dolly => Box::new(DollyManager::new()),
        Technique::Sgc => Box::new(SgcManager::new()),
        Technique::NearestFit => Box::new(NearestFitManager::new()),
        Technique::Late => Box::new(LateManager::new()),
        Technique::Rpps => Box::new(RppsManager::new()),
        Technique::None => Box::new(NullManager),
    })
}

/// Instantiate the manager for a technique.
///
/// Prediction-based techniques (START, IGRU-SD) consume the AOT models;
/// the reactive baselines are model-free.
pub fn build_manager(technique: Technique, models: &Models, cfg: &SimConfig) -> Result<Box<dyn Manager>> {
    Ok(match technique {
        Technique::Start => {
            let mut predictor = StartPredictor::new(Rc::clone(&models.start), cfg.k_straggler);
            if cfg.window_steps > 0 {
                predictor.window_steps = cfg.window_steps;
            }
            let mut mgr = StartManager::new(predictor);
            mgr.predict_every = cfg.predict_every.max(1);
            Box::new(mgr)
        }
        Technique::IgruSd => {
            Box::new(IgruSdManager::new(IgruPredictor::new(Rc::clone(&models.igru), 1.15)))
        }
        // Reachable only if this match and `model_free_manager` ever
        // drift apart — surfaced as an error, not a panic, so one bad
        // cell cannot take down a batch.
        other => model_free_manager(other).ok_or_else(|| {
            anyhow!("technique {other:?} has no model-free manager and no model constructor")
        })?,
    })
}

/// Run one simulation cell (one technique, one config) end to end.
pub fn run_one(cfg: &SimConfig, models: &Models) -> Result<RunMetrics> {
    Ok(run_one_traced(cfg, models, TraceSink::off())?.0)
}

/// [`run_one`] with an event sink installed (sim/trace.rs): returns the
/// sink alongside the metrics.  File sinks still need
/// `TraceSink::finish` to flush.
pub fn run_one_traced(
    cfg: &SimConfig,
    models: &Models,
    sink: TraceSink,
) -> Result<(RunMetrics, TraceSink)> {
    let scheduler = crate::scheduler::build(cfg.scheduler, Pcg::new(cfg.seed, 0x5C8E));
    let manager = build_manager(cfg.technique, models, cfg)?;
    let mut sim = Simulation::new(cfg.clone(), &models.manifest, scheduler, manager);
    sim.set_trace(sink);
    Ok(sim.run_traced())
}

/// Run a *model-free* cell without any artifact directory: uses the real
/// manifest when one is discoverable, else the canned test-default
/// (adequate — model-free managers never dispatch the AOT models).  The
/// `simulate` CLI falls back to this, and CI uses it to produce a sample
/// trace on a bare checkout.
pub fn run_one_hermetic(cfg: &SimConfig, sink: TraceSink) -> Result<(RunMetrics, TraceSink)> {
    let manager = model_free_manager(cfg.technique).ok_or_else(|| {
        anyhow::anyhow!(
            "technique {:?} needs the AOT models; no artifact directory available",
            cfg.technique
        )
    })?;
    let manifest =
        Manifest::load(crate::find_artifact_dir()).unwrap_or_else(|_| Manifest::test_default());
    let scheduler = crate::scheduler::build(cfg.scheduler, Pcg::new(cfg.seed, 0x5C8E));
    let mut sim = Simulation::new(cfg.clone(), &manifest, scheduler, manager);
    sim.set_trace(sink);
    Ok(sim.run_traced())
}

/// A labelled experiment cell.
#[derive(Clone)]
pub struct Cell {
    pub label: String,
    pub cfg: SimConfig,
}

/// Worker-side manager constructor override (chaos/fault-injection hook
/// for the resilience test suite, and a general way to run custom
/// managers through the batch machinery).  Called on the worker thread
/// once per cell attempt; when set, workers skip `Models::load` entirely
/// and run hermetic (canned-manifest fallback, like
/// [`run_one_hermetic`]).
pub type ManagerFactory = Arc<dyn Fn(&SimConfig) -> Result<Box<dyn Manager>> + Send + Sync>;

/// Default bounded-retry budget: one initial attempt plus this many
/// retries per cell.
pub const DEFAULT_RETRIES: u32 = 2;

/// Options for [`run_many_opts`] / [`run_many_cells`].
#[derive(Clone)]
pub struct RunOpts {
    /// When set, each cell streams a JSONL event trace to
    /// `<dir>/<unique sanitized label>.jsonl` (collision-deduplicated,
    /// see [`unique_stems`]).  Cells restored from the journal do not
    /// re-write their trace files.
    pub trace_dir: Option<PathBuf>,
    /// Crash-safe results journal (`results.jsonl`): every completed
    /// cell is appended and fsync'd as soon as the leader collects it.
    pub journal: Option<PathBuf>,
    /// Reuse existing journal records: cells whose `(label, config
    /// digest)` key is already journaled are skipped and their journaled
    /// metrics returned verbatim (bit-identical resume).  Without this,
    /// an existing journal file is truncated.
    pub resume: bool,
    /// Partial-results mode: run every cell to completion and report
    /// per-cell `Result`s.  When off (the default), the leader stops
    /// dispatching after the first failed cell (queued cells are
    /// cancelled) and [`run_many_opts`] surfaces the first error.
    pub keep_going: bool,
    /// Extra attempts after the first, per cell (bounded retry for
    /// transient failures — PJRT/artifact load, trace-sink I/O, panics).
    pub retries: u32,
    /// Deterministic capped exponential backoff between attempts:
    /// `min(backoff_base · 2^(attempt−1), backoff_cap)`.  No jitter — a
    /// replayed batch sleeps the same schedule.
    pub backoff_base: Duration,
    pub backoff_cap: Duration,
    /// Per-cell wall-clock deadline, enforced cooperatively by the
    /// engine at interval boundaries (`Simulation::set_deadline`); a
    /// leader-side watchdog additionally reports cells that overshoot
    /// (e.g. hung inside a PJRT dispatch, which cannot be preempted).
    pub cell_timeout: Option<Duration>,
    /// Chaos/testing hook: build managers through this factory instead
    /// of `build_manager` + `Models`.
    pub manager_override: Option<ManagerFactory>,
    /// Compact the journal after the batch completes with every journal
    /// append intact: rewrite `results.jsonl` keeping only the last
    /// record per `(label, digest)` key ([`journal::compact`]).  Resume
    /// from the compacted journal is bit-identical; crash/retry
    /// re-appends and torn lines are dropped.
    pub compact: bool,
}

impl Default for RunOpts {
    fn default() -> RunOpts {
        RunOpts {
            trace_dir: None,
            journal: None,
            resume: false,
            keep_going: false,
            retries: DEFAULT_RETRIES,
            backoff_base: Duration::from_millis(100),
            backoff_cap: Duration::from_secs(2),
            cell_timeout: None,
            manager_override: None,
            compact: false,
        }
    }
}

/// The outcome of one cell in a batch.
pub struct CellOutcome {
    pub label: String,
    pub result: Result<RunMetrics>,
    /// Attempts actually executed (0 when restored from the journal).
    pub attempts: u32,
    /// The metrics were restored from the results journal, not re-run.
    pub from_journal: bool,
}

/// Turn a cell label into a safe file stem (`fig10|Grass|42` →
/// `fig10_Grass_42`).  Not collision-free — two labels can sanitize to
/// the same stem; batch file naming goes through [`unique_stems`].
pub fn sanitize_label(label: &str) -> String {
    label
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '.' || c == '-' { c } else { '_' })
        .collect()
}

/// Collision-free file stems for a batch, in submission order: the first
/// label to claim a sanitized stem keeps it, later colliding labels get
/// an `__2`, `__3`, … suffix (checked against the whole used set, so a
/// generated suffix can never collide with another label's natural
/// stem).
pub fn unique_stems(cells: &[Cell]) -> Vec<String> {
    let mut used: HashSet<String> = HashSet::new();
    let mut stems = Vec::with_capacity(cells.len());
    for cell in cells {
        let base = sanitize_label(&cell.label);
        let mut stem = base.clone();
        let mut k = 2usize;
        while !used.insert(stem.clone()) {
            stem = format!("{base}__{k}");
            k += 1;
        }
        stems.push(stem);
    }
    stems
}

/// Deterministic capped exponential backoff before retry `retry` (1-based:
/// the sleep before the first retry is `base`, then `2·base`, `4·base`, …
/// capped at `cap`).
pub fn backoff_delay(retry: u32, base: Duration, cap: Duration) -> Duration {
    let shift = retry.saturating_sub(1).min(16);
    base.checked_mul(1u32 << shift).unwrap_or(cap).min(cap)
}

fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    payload
        .downcast_ref::<&str>()
        .map(|s| s.to_string())
        .or_else(|| payload.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "<non-string panic payload>".into())
}

/// What a worker has to run cells with.
enum WorkerCtx {
    /// Full AOT model bundle (every technique runs).
    Loaded(Models),
    /// No models on this worker: either the batch runs with a manager
    /// override (hermetic), or `Models::load` exhausted its retries and
    /// the worker degraded to model-free cells (`why` carries the load
    /// error; model-requiring cells become per-cell errors instead of
    /// killing the batch).
    ModelFree { manifest: Manifest, why: Option<String> },
}

/// One attempt at one cell.  Panics are caught by the caller.
fn run_cell_attempt(cell: &Cell, stem: &str, ctx: &WorkerCtx, opts: &RunOpts) -> Result<RunMetrics> {
    let sink = match &opts.trace_dir {
        Some(d) => TraceSink::file(d.join(format!("{stem}.jsonl")))?,
        None => TraceSink::off(),
    };
    let scheduler = crate::scheduler::build(cell.cfg.scheduler, Pcg::new(cell.cfg.seed, 0x5C8E));
    let (manager, manifest): (Box<dyn Manager>, &Manifest) = match (&opts.manager_override, ctx) {
        (Some(factory), WorkerCtx::Loaded(models)) => (factory(&cell.cfg)?, &models.manifest),
        (Some(factory), WorkerCtx::ModelFree { manifest, .. }) => (factory(&cell.cfg)?, manifest),
        (None, WorkerCtx::Loaded(models)) => {
            (build_manager(cell.cfg.technique, models, &cell.cfg)?, &models.manifest)
        }
        (None, WorkerCtx::ModelFree { manifest, why }) => {
            let mgr = model_free_manager(cell.cfg.technique).ok_or_else(|| {
                anyhow!(
                    "technique {:?} needs the AOT models, unavailable on this worker{}",
                    cell.cfg.technique,
                    why.as_ref().map(|e| format!(" ({e})")).unwrap_or_default()
                )
            })?;
            (mgr, manifest)
        }
    };
    let mut sim = Simulation::new(cell.cfg.clone(), manifest, scheduler, manager);
    sim.set_trace(sink);
    if let Some(timeout) = opts.cell_timeout {
        sim.set_deadline(Instant::now() + timeout);
    }
    let (metrics, mut sink, timed_out) = sim.run_traced_outcome();
    sink.finish()?;
    if timed_out {
        bail!(
            "cell {:?} exceeded its {:.1}s wall-clock deadline",
            cell.label,
            opts.cell_timeout.unwrap_or_default().as_secs_f64()
        );
    }
    Ok(metrics)
}

/// Retry loop around [`run_cell_attempt`] with panic isolation: a panic
/// anywhere inside the cell (manager, engine, trace sink) becomes a
/// per-cell error; sibling cells are never lost.  Returns the result and
/// the number of attempts executed.
fn run_cell(cell: &Cell, stem: &str, ctx: &WorkerCtx, opts: &RunOpts) -> (Result<RunMetrics>, u32) {
    let max_attempts = opts.retries.saturating_add(1);
    let mut last_err = None;
    for attempt in 1..=max_attempts {
        if attempt > 1 {
            std::thread::sleep(backoff_delay(attempt - 1, opts.backoff_base, opts.backoff_cap));
        }
        match catch_unwind(AssertUnwindSafe(|| run_cell_attempt(cell, stem, ctx, opts))) {
            Ok(Ok(metrics)) => return (Ok(metrics), attempt),
            Ok(Err(e)) => last_err = Some(e),
            Err(payload) => {
                last_err = Some(anyhow!("cell panicked: {}", panic_message(payload)))
            }
        }
    }
    let err = last_err
        .unwrap_or_else(|| anyhow!("no attempts executed"))
        .context(format!("cell {:?} failed after {max_attempts} attempt(s)", cell.label));
    (Err(err), max_attempts)
}

/// Load the per-worker model bundle with bounded retry + backoff; on
/// exhaustion the worker degrades to model-free cells instead of killing
/// the batch (master–worker restart/redundancy, DESIGN.md §12).
fn load_worker_ctx(art_dir: &std::path::Path, opts: &RunOpts) -> WorkerCtx {
    let hermetic_manifest =
        || Manifest::load(crate::find_artifact_dir()).unwrap_or_else(|_| Manifest::test_default());
    if opts.manager_override.is_some() {
        return WorkerCtx::ModelFree { manifest: hermetic_manifest(), why: None };
    }
    let max_attempts = opts.retries.saturating_add(1);
    let mut last_err = None;
    for attempt in 1..=max_attempts {
        if attempt > 1 {
            std::thread::sleep(backoff_delay(attempt - 1, opts.backoff_base, opts.backoff_cap));
        }
        match catch_unwind(AssertUnwindSafe(|| Models::load(art_dir))) {
            Ok(Ok(models)) => return WorkerCtx::Loaded(models),
            Ok(Err(e)) => last_err = Some(format!("{e:#}")),
            Err(payload) => last_err = Some(format!("panic: {}", panic_message(payload))),
        }
    }
    let why = last_err.unwrap_or_else(|| "unknown".into());
    eprintln!(
        "note: worker degraded to model-free cells — Models::load failed after \
         {max_attempts} attempt(s): {why}"
    );
    WorkerCtx::ModelFree { manifest: hermetic_manifest(), why: Some(why) }
}

/// Run cells on a worker pool.  Each worker owns its own PJRT client (the
/// leader/worker topology: the leader distributes cells over an mpsc
/// queue and collects `(label, metrics)` results).
pub fn run_many(cells: Vec<Cell>, threads: usize, art_dir: PathBuf) -> Result<Vec<(String, RunMetrics)>> {
    run_many_opts(cells, threads, art_dir, RunOpts::default())
}

/// [`run_many`] with observability/resilience options, strict mode: the
/// first failed cell fails the batch (after retries; queued cells are
/// cancelled).  Results come back in *submission order* (ordered
/// reduction: workers tag each result with its cell index and the leader
/// slots it), so downstream tables are deterministic regardless of
/// worker interleaving.
pub fn run_many_opts(
    cells: Vec<Cell>,
    threads: usize,
    art_dir: PathBuf,
    opts: RunOpts,
) -> Result<Vec<(String, RunMetrics)>> {
    let keep_going = opts.keep_going;
    let outcomes = run_many_cells(cells, threads, art_dir, opts)?;
    let mut out = Vec::with_capacity(outcomes.len());
    let mut first_err = None;
    for o in outcomes {
        match o.result {
            Ok(m) => out.push((o.label, m)),
            Err(e) => {
                first_err.get_or_insert(e);
            }
        }
    }
    match first_err {
        Some(e) if !keep_going => Err(e),
        _ => Ok(out),
    }
}

/// The fault-tolerant batch engine (DESIGN.md §12): per-cell panic
/// isolation, bounded retry with deterministic capped backoff, per-cell
/// deadlines with a leader-side watchdog, journal-backed resume, and
/// per-cell `Result`s in submission order.  Returns `Err` only for
/// batch-level infrastructure failures (journal I/O, queue seeding) —
/// cell failures live in the per-cell outcomes.
pub fn run_many_cells(
    cells: Vec<Cell>,
    threads: usize,
    art_dir: PathBuf,
    opts: RunOpts,
) -> Result<Vec<CellOutcome>> {
    let n_cells = cells.len();
    let stems = unique_stems(&cells);
    let labels: Vec<String> = cells.iter().map(|c| c.label.clone()).collect();
    let digests: Vec<String> = cells.iter().map(|c| journal::cfg_digest(&c.cfg)).collect();

    // Resume: restore journaled cells without re-running them.
    let journal_map = match (&opts.journal, opts.resume) {
        (Some(path), true) => journal::load_map(path)?,
        _ => HashMap::new(),
    };
    let mut writer = match &opts.journal {
        Some(path) => Some(journal::Journal::open(path, opts.resume)?),
        None => None,
    };

    let mut outcomes: Vec<Option<CellOutcome>> = (0..n_cells).map(|_| None).collect();
    let mut work_items = Vec::new();
    for (idx, cell) in cells.into_iter().enumerate() {
        let key = (labels[idx].clone(), digests[idx].clone());
        if let Some(m) = journal_map.get(&key) {
            outcomes[idx] = Some(CellOutcome {
                label: labels[idx].clone(),
                result: Ok(m.clone()),
                attempts: 0,
                from_journal: true,
            });
        } else {
            work_items.push((idx, cell, stems[idx].clone()));
        }
    }

    if !work_items.is_empty() {
        let threads = threads.max(1).min(work_items.len());
        let (work_tx, work_rx) = mpsc::channel::<(usize, Cell, String)>();
        let work_rx = Arc::new(Mutex::new(work_rx));
        let (res_tx, res_rx) = mpsc::channel::<(usize, Result<RunMetrics>, u32)>();
        for item in work_items {
            work_tx
                .send(item)
                .map_err(|e| anyhow!("seeding the work queue failed: {e}"))?;
        }
        drop(work_tx);

        // In-flight table feeding the watchdog (cell index → label, start).
        let inflight: Arc<Mutex<HashMap<usize, (String, Instant)>>> =
            Arc::new(Mutex::new(HashMap::new()));
        let stop = Arc::new(AtomicBool::new(false));
        let watchdog = opts.cell_timeout.map(|timeout| {
            let inflight = Arc::clone(&inflight);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                let poll = (timeout / 4).clamp(Duration::from_millis(10), Duration::from_secs(5));
                let mut warned: HashSet<usize> = HashSet::new();
                while !stop.load(Ordering::Relaxed) {
                    std::thread::sleep(poll);
                    let now = Instant::now();
                    for (&idx, (label, started)) in inflight.lock().unwrap().iter() {
                        let elapsed = now.duration_since(*started);
                        if elapsed > timeout.saturating_mul(2) && warned.insert(idx) {
                            eprintln!(
                                "[watchdog] cell {label:?} running {:.1}s past its {:.1}s \
                                 deadline (the engine aborts it at the next interval \
                                 boundary; a hang inside a native call cannot be preempted)",
                                elapsed.as_secs_f64(),
                                timeout.as_secs_f64()
                            );
                        }
                    }
                }
            })
        });

        let mut handles = Vec::new();
        for _ in 0..threads {
            let rx = Arc::clone(&work_rx);
            let tx = res_tx.clone();
            let dir = art_dir.clone();
            let opts = opts.clone();
            let inflight = Arc::clone(&inflight);
            handles.push(std::thread::spawn(move || {
                let ctx = load_worker_ctx(&dir, &opts);
                loop {
                    let item = { rx.lock().unwrap().recv() };
                    let Ok((idx, cell, stem)) = item else { break };
                    inflight.lock().unwrap().insert(idx, (cell.label.clone(), Instant::now()));
                    let (result, attempts) = run_cell(&cell, &stem, &ctx, &opts);
                    inflight.lock().unwrap().remove(&idx);
                    if tx.send((idx, result, attempts)).is_err() {
                        break;
                    }
                }
            }));
        }
        drop(res_tx);

        let mut journal_err: Option<anyhow::Error> = None;
        for (idx, result, attempts) in res_rx {
            if let (Ok(m), Some(w), None) = (&result, writer.as_mut(), journal_err.as_ref()) {
                // A journal append failure breaks the crash-safety
                // contract: record it as a batch-level error (after
                // letting the in-flight cells finish).
                if let Err(e) = w.append(&labels[idx], &digests[idx], attempts, m) {
                    journal_err = Some(e);
                }
            }
            let failed = result.is_err();
            outcomes[idx] = Some(CellOutcome {
                label: labels[idx].clone(),
                result,
                attempts,
                from_journal: false,
            });
            if failed && !opts.keep_going {
                // Fail fast: cancel everything still queued (in-flight
                // cells finish and are collected normally).
                let rx = work_rx.lock().unwrap();
                while let Ok((idx, _, _)) = rx.try_recv() {
                    outcomes[idx] = Some(CellOutcome {
                        label: labels[idx].clone(),
                        result: Err(anyhow!(
                            "cancelled: an earlier cell failed (strict mode; \
                             use keep_going for partial results)"
                        )),
                        attempts: 0,
                        from_journal: false,
                    });
                }
            }
        }
        stop.store(true, Ordering::Relaxed);
        for h in handles {
            let _ = h.join();
        }
        if let Some(h) = watchdog {
            let _ = h.join();
        }
        if let Some(e) = journal_err {
            return Err(e);
        }
    }

    // Post-batch journal hygiene: close the writer, then rewrite the file
    // keeping only the last record per key.  Only after a fully journaled
    // batch — compaction must never race an open append handle.
    if opts.compact {
        if let Some(path) = &opts.journal {
            drop(writer.take());
            let (kept, dropped) = journal::compact(path)?;
            if dropped > 0 {
                eprintln!(
                    "note: compacted journal {} ({kept} records kept, {dropped} lines dropped)",
                    path.display()
                );
            }
        }
    }

    Ok(outcomes
        .into_iter()
        .enumerate()
        .map(|(idx, slot)| {
            slot.unwrap_or_else(|| CellOutcome {
                label: labels[idx].clone(),
                result: Err(anyhow!("cell produced no result (worker terminated abnormally)")),
                attempts: 0,
                from_journal: false,
            })
        })
        .collect())
}

/// Human-readable failure summary for a batch, `None` when every cell
/// succeeded.
pub fn failure_summary(outcomes: &[CellOutcome]) -> Option<String> {
    let failures: Vec<&CellOutcome> = outcomes.iter().filter(|o| o.result.is_err()).collect();
    if failures.is_empty() {
        return None;
    }
    let mut s = format!("{} of {} cells failed:", failures.len(), outcomes.len());
    for o in failures {
        let err = o.result.as_ref().err().map(|e| format!("{e:#}")).unwrap_or_default();
        s.push_str(&format!("\n  {} [{} attempt(s)]: {err}", o.label, o.attempts));
    }
    Some(s)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cell(label: &str) -> Cell {
        Cell { label: label.into(), cfg: SimConfig::test_defaults() }
    }

    #[test]
    fn sanitize_collisions_get_unique_stems() {
        // Both sanitize to `fig_A_1`; the journal/trace files must not
        // silently overwrite each other.
        let cells =
            [cell("fig|A|1"), cell("fig_A_1"), cell("fig|A|1"), cell("fig_A_1__2"), cell("x")];
        let stems = unique_stems(&cells);
        assert_eq!(stems[0], "fig_A_1");
        assert_eq!(stems[1], "fig_A_1__2");
        assert_eq!(stems[2], "fig_A_1__3");
        // A label whose *natural* stem matches a generated suffix still
        // gets a fresh name.
        assert_eq!(stems[3], "fig_A_1__2__2");
        assert_eq!(stems[4], "x");
        let unique: HashSet<&String> = stems.iter().collect();
        assert_eq!(unique.len(), stems.len());
    }

    #[test]
    fn backoff_is_deterministic_and_capped() {
        let base = Duration::from_millis(100);
        let cap = Duration::from_secs(2);
        assert_eq!(backoff_delay(1, base, cap), Duration::from_millis(100));
        assert_eq!(backoff_delay(2, base, cap), Duration::from_millis(200));
        assert_eq!(backoff_delay(3, base, cap), Duration::from_millis(400));
        assert_eq!(backoff_delay(6, base, cap), cap);
        assert_eq!(backoff_delay(60, base, cap), cap); // shift saturates
        assert_eq!(backoff_delay(1, Duration::ZERO, cap), Duration::ZERO);
    }

    #[test]
    fn build_manager_covers_every_technique_without_panicking() {
        // The `other` arm must stay total: every technique either builds
        // model-free or is one of the model-backed arms (which we cannot
        // construct without artifacts — they are explicitly matched, so
        // reaching `other` with them is impossible).
        for t in Technique::paper_set() {
            if matches!(t, Technique::Start | Technique::IgruSd) {
                assert!(model_free_manager(t).is_none());
            } else {
                assert!(model_free_manager(t).is_some(), "{t:?}");
            }
        }
    }
}
