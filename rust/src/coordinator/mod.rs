//! The START coordinator (L3 leader): wires the AOT models, scheduler,
//! technique manager and simulator together; runs experiment cells on a
//! worker-thread pool (one PJRT client per worker — executables are not
//! shared across threads).

pub mod start_manager;

pub use start_manager::StartManager;

use crate::baselines::*;
use crate::config::{SimConfig, Technique};
use crate::predictor::{IgruPredictor, StartPredictor};
use crate::runtime::{IgruModel, Manifest, PjrtRuntime, StartModel};

use crate::sim::engine::{Manager, NullManager, Simulation};
use crate::sim::metrics::RunMetrics;
use crate::sim::trace::TraceSink;
use crate::util::rng::Pcg;
use anyhow::{Context, Result};
use std::path::PathBuf;
use std::rc::Rc;
use std::sync::mpsc;
use std::sync::Arc;

/// Per-worker model bundle (PJRT client + compiled executables).
pub struct Models {
    pub runtime: PjrtRuntime,
    pub manifest: Manifest,
    /// Compiled executables are shared (Rc) across every manager built on
    /// this worker — re-parsing + re-compiling the 1.1 MB HLO text per
    /// experiment cell cost ~1 s/cell before this (DESIGN.md §7).
    pub start: Rc<StartModel>,
    pub igru: Rc<IgruModel>,
}

impl Models {
    /// Load everything from an artifact directory.
    pub fn load(art_dir: impl Into<PathBuf>) -> Result<Models> {
        let dir = art_dir.into();
        let manifest = Manifest::load(&dir).context("loading manifest")?;
        let runtime = PjrtRuntime::new(&dir)?;
        let start = Rc::new(StartModel::load(&runtime, &manifest)?);
        let igru = Rc::new(IgruModel::load(&runtime, &manifest)?);
        Ok(Models { runtime, manifest, start, igru })
    }

    /// Load from the default artifact location.
    pub fn load_default() -> Result<Models> {
        Self::load(crate::find_artifact_dir())
    }
}

/// Instantiate a manager that needs no AOT models (the reactive
/// baselines); `None` for the prediction-based techniques (START,
/// IGRU-SD).  Shared by [`build_manager`], the hermetic run path, and
/// the parity/replay test suites.
pub fn model_free_manager(technique: Technique) -> Option<Box<dyn Manager>> {
    Some(match technique {
        Technique::Start | Technique::IgruSd => return None,
        Technique::Wrangler => Box::new(WranglerManager::new()),
        Technique::Grass => Box::new(GrassManager::new()),
        Technique::Dolly => Box::new(DollyManager::new()),
        Technique::Sgc => Box::new(SgcManager::new()),
        Technique::NearestFit => Box::new(NearestFitManager::new()),
        Technique::Late => Box::new(LateManager::new()),
        Technique::Rpps => Box::new(RppsManager::new()),
        Technique::None => Box::new(NullManager),
    })
}

/// Instantiate the manager for a technique.
///
/// Prediction-based techniques (START, IGRU-SD) consume the AOT models;
/// the reactive baselines are model-free.
pub fn build_manager(technique: Technique, models: &Models, cfg: &SimConfig) -> Result<Box<dyn Manager>> {
    Ok(match technique {
        Technique::Start => {
            let mut predictor = StartPredictor::new(Rc::clone(&models.start), cfg.k_straggler);
            if cfg.window_steps > 0 {
                predictor.window_steps = cfg.window_steps;
            }
            let mut mgr = StartManager::new(predictor);
            mgr.predict_every = cfg.predict_every.max(1);
            Box::new(mgr)
        }
        Technique::IgruSd => {
            Box::new(IgruSdManager::new(IgruPredictor::new(Rc::clone(&models.igru), 1.15)))
        }
        other => model_free_manager(other).expect("model-free technique"),
    })
}

/// Run one simulation cell (one technique, one config) end to end.
pub fn run_one(cfg: &SimConfig, models: &Models) -> Result<RunMetrics> {
    Ok(run_one_traced(cfg, models, TraceSink::off())?.0)
}

/// [`run_one`] with an event sink installed (sim/trace.rs): returns the
/// sink alongside the metrics.  File sinks still need
/// `TraceSink::finish` to flush.
pub fn run_one_traced(
    cfg: &SimConfig,
    models: &Models,
    sink: TraceSink,
) -> Result<(RunMetrics, TraceSink)> {
    let scheduler = crate::scheduler::build(cfg.scheduler, Pcg::new(cfg.seed, 0x5C8E));
    let manager = build_manager(cfg.technique, models, cfg)?;
    let mut sim = Simulation::new(cfg.clone(), &models.manifest, scheduler, manager);
    sim.set_trace(sink);
    Ok(sim.run_traced())
}

/// Run a *model-free* cell without any artifact directory: uses the real
/// manifest when one is discoverable, else the canned test-default
/// (adequate — model-free managers never dispatch the AOT models).  The
/// `simulate` CLI falls back to this, and CI uses it to produce a sample
/// trace on a bare checkout.
pub fn run_one_hermetic(cfg: &SimConfig, sink: TraceSink) -> Result<(RunMetrics, TraceSink)> {
    let manager = model_free_manager(cfg.technique).ok_or_else(|| {
        anyhow::anyhow!(
            "technique {:?} needs the AOT models; no artifact directory available",
            cfg.technique
        )
    })?;
    let manifest =
        Manifest::load(crate::find_artifact_dir()).unwrap_or_else(|_| Manifest::test_default());
    let scheduler = crate::scheduler::build(cfg.scheduler, Pcg::new(cfg.seed, 0x5C8E));
    let mut sim = Simulation::new(cfg.clone(), &manifest, scheduler, manager);
    sim.set_trace(sink);
    Ok(sim.run_traced())
}

/// A labelled experiment cell.
#[derive(Clone)]
pub struct Cell {
    pub label: String,
    pub cfg: SimConfig,
}

/// Options for [`run_many_opts`].
#[derive(Clone, Default)]
pub struct RunOpts {
    /// When set, each cell streams a JSONL event trace to
    /// `<dir>/<sanitized label>.jsonl`.
    pub trace_dir: Option<PathBuf>,
}

/// Turn a cell label into a safe file stem (`fig10|Grass|42` →
/// `fig10_Grass_42`).
pub fn sanitize_label(label: &str) -> String {
    label
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '.' || c == '-' { c } else { '_' })
        .collect()
}

/// Run cells on a worker pool.  Each worker owns its own PJRT client (the
/// leader/worker topology: the leader distributes cells over an mpsc
/// queue and collects `(label, metrics)` results).
pub fn run_many(cells: Vec<Cell>, threads: usize, art_dir: PathBuf) -> Result<Vec<(String, RunMetrics)>> {
    run_many_opts(cells, threads, art_dir, RunOpts::default())
}

/// [`run_many`] with observability options.  Results come back in
/// *submission order* (ordered reduction: workers tag each result with
/// its cell index and the leader slots it), so downstream tables are
/// deterministic regardless of worker interleaving.
pub fn run_many_opts(
    cells: Vec<Cell>,
    threads: usize,
    art_dir: PathBuf,
    opts: RunOpts,
) -> Result<Vec<(String, RunMetrics)>> {
    let threads = threads.max(1).min(cells.len().max(1));
    let (work_tx, work_rx) = mpsc::channel::<(usize, Cell)>();
    let work_rx = Arc::new(std::sync::Mutex::new(work_rx));
    let (res_tx, res_rx) = mpsc::channel::<(usize, Result<(String, RunMetrics)>)>();
    let n_cells = cells.len();
    for item in cells.into_iter().enumerate() {
        work_tx.send(item).unwrap();
    }
    drop(work_tx);
    let mut handles = Vec::new();
    for _ in 0..threads {
        let rx = Arc::clone(&work_rx);
        let tx = res_tx.clone();
        let dir = art_dir.clone();
        let opts = opts.clone();
        handles.push(std::thread::spawn(move || {
            let models = match Models::load(dir) {
                Ok(m) => m,
                Err(e) => {
                    let _ = tx.send((usize::MAX, Err(e)));
                    return;
                }
            };
            loop {
                let cell = { rx.lock().unwrap().recv() };
                let Ok((idx, cell)) = cell else { break };
                let result = (|| -> Result<(String, RunMetrics)> {
                    let sink = match &opts.trace_dir {
                        Some(d) => {
                            TraceSink::file(d.join(format!("{}.jsonl", sanitize_label(&cell.label))))?
                        }
                        None => TraceSink::off(),
                    };
                    let (m, mut sink) = run_one_traced(&cell.cfg, &models, sink)?;
                    sink.finish()?;
                    Ok((cell.label, m))
                })();
                if tx.send((idx, result)).is_err() {
                    break;
                }
            }
        }));
    }
    drop(res_tx);
    let mut slots: Vec<Option<(String, RunMetrics)>> = (0..n_cells).map(|_| None).collect();
    let mut first_err = None;
    for (idx, r) in res_rx {
        match r {
            Ok(pair) if idx < n_cells => slots[idx] = Some(pair),
            Ok(_) => {}
            Err(e) => {
                first_err.get_or_insert(e);
            }
        }
    }
    for h in handles {
        let _ = h.join();
    }
    if let Some(e) = first_err {
        return Err(e);
    }
    slots
        .into_iter()
        .enumerate()
        .map(|(i, s)| s.ok_or_else(|| anyhow::anyhow!("cell {i} produced no result")))
        .collect()
}
