//! Crash-safe results journal for the coordinator (DESIGN.md §12).
//!
//! One JSONL record per *completed* experiment cell, appended and fsync'd
//! as soon as the leader collects the cell's metrics:
//!
//! ```json
//! {"cell":"fig6|Grass|42","cfg":"9f3a…16 hex…","attempts":1,"metrics":{…}}
//! ```
//!
//! Records are keyed by `(label, config digest)`, so a journal survives
//! label reuse across figures and silently invalidates itself when the
//! cell's configuration changes.  The metrics payload is the lossless
//! round-trip form from `sim::trace::{metrics_to_json, metrics_from_json}`
//! (bit-exact f64s, exact profiler counters): a batch resumed from the
//! journal is indistinguishable from an uninterrupted run.
//!
//! Crash model: the process may die at any point.  Appends are
//! write-then-fsync, so after a crash the file holds only complete
//! records plus at most one torn final line; [`load_map`] skips
//! unparseable lines (warning to stderr), which is safe because the
//! journal is a pure cache — a skipped record just means the cell re-runs
//! deterministically.

use crate::config::SimConfig;
use crate::sim::metrics::RunMetrics;
use crate::sim::trace::{metrics_from_json, metrics_to_json};
use crate::util::json::{self, Json};
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::io::Write;
use std::path::{Path, PathBuf};

/// Stable 64-bit digest of a cell configuration (FNV-1a over the
/// canonical `Debug` rendering — every config field participates, so any
/// knob change yields a new digest and invalidates journaled results for
/// that cell).
pub fn cfg_digest(cfg: &SimConfig) -> String {
    let text = format!("{cfg:?}");
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in text.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    format!("{h:016x}")
}

/// Journal key: cell label + config digest.
pub type CellKey = (String, String);

/// Parse the journal at `path` into a `(label, digest) → metrics` map.
/// Later records win (a resumed batch may re-append a cell that failed
/// mid-write earlier).  Unparseable lines — e.g. the torn final line of a
/// crashed run — are skipped with a warning.  A missing file is an empty
/// journal.
pub fn load_map(path: &Path) -> Result<HashMap<CellKey, RunMetrics>> {
    let mut map = HashMap::new();
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(map),
        Err(e) => return Err(e).with_context(|| format!("reading journal {}", path.display())),
    };
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        match parse_record(line) {
            Ok((key, m)) => {
                map.insert(key, m);
            }
            Err(e) => {
                eprintln!(
                    "note: journal {} line {}: skipping unreadable record ({e:#}); \
                     the cell will re-run",
                    path.display(),
                    i + 1
                );
            }
        }
    }
    Ok(map)
}

fn parse_record(line: &str) -> Result<(CellKey, RunMetrics)> {
    let v = json::parse(line)?;
    let label = v.req_str("cell")?.to_string();
    let digest = v.req_str("cfg")?.to_string();
    let metrics = metrics_from_json(
        v.get("metrics").ok_or_else(|| anyhow::anyhow!("missing metrics"))?,
    )?;
    Ok(((label, digest), metrics))
}

/// Compact the journal at `path` in place: rewrite it keeping only the
/// **last** record per `(label, digest)` key — the one [`load_map`]
/// would return — dropping superseded duplicates (from crash/retry
/// re-appends) and torn/unparseable lines.  Surviving lines are kept
/// byte-for-byte (no re-serialization), so a resume from the compacted
/// journal is bit-identical to a resume from the original.  Keys keep
/// their first-appearance order.  The rewrite goes through a temp file,
/// fsync, then an atomic rename — a crash mid-compaction leaves either
/// the old or the new journal, never a torn one.  A missing file is a
/// no-op.  Returns `(records kept, lines dropped)`.
pub fn compact(path: &Path) -> Result<(usize, usize)> {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok((0, 0)),
        Err(e) => return Err(e).with_context(|| format!("reading journal {}", path.display())),
    };
    // Last line per key wins; keys remember where they first appeared.
    let mut order: Vec<CellKey> = Vec::new();
    let mut last: HashMap<CellKey, &str> = HashMap::new();
    let mut total_lines = 0usize;
    for line in text.lines() {
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        total_lines += 1;
        if let Ok((key, _)) = parse_record(trimmed) {
            if !last.contains_key(&key) {
                order.push(key.clone());
            }
            last.insert(key, line);
        }
    }
    let kept = order.len();
    let dropped = total_lines - kept;
    if dropped == 0 {
        return Ok((kept, 0));
    }
    let tmp = path.with_extension("jsonl.compact-tmp");
    {
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        for key in &order {
            writeln!(f, "{}", last[key])
                .with_context(|| format!("writing {}", tmp.display()))?;
        }
        f.sync_data().with_context(|| format!("syncing {}", tmp.display()))?;
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("replacing journal {}", path.display()))?;
    Ok((kept, dropped))
}

/// Append-only journal writer.  Every [`Journal::append`] is flushed and
/// fsync'd before returning — a completed cell is durable the moment the
/// leader records it.
pub struct Journal {
    file: std::fs::File,
    path: PathBuf,
}

impl Journal {
    /// Open the journal for writing, creating parent directories.  With
    /// `append` the existing records are kept (resume); otherwise the
    /// file is truncated (a fresh batch).
    pub fn open(path: impl Into<PathBuf>, append: bool) -> Result<Journal> {
        let path = path.into();
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("creating {}", dir.display()))?;
            }
        }
        let file = if append {
            std::fs::OpenOptions::new().create(true).append(true).open(&path)
        } else {
            std::fs::File::create(&path)
        }
        .with_context(|| format!("opening journal {}", path.display()))?;
        Ok(Journal { file, path })
    }

    /// Durably record one completed cell (write + flush + fsync).
    pub fn append(
        &mut self,
        label: &str,
        digest: &str,
        attempts: u32,
        metrics: &RunMetrics,
    ) -> Result<()> {
        let record = Json::obj(vec![
            ("cell", Json::str(label)),
            ("cfg", Json::str(digest)),
            ("attempts", Json::Num(attempts as f64)),
            ("metrics", metrics_to_json(metrics)),
        ]);
        writeln!(self.file, "{}", record.dump())
            .and_then(|()| self.file.sync_data())
            .with_context(|| format!("appending to journal {}", self.path.display()))
    }

    /// The journal's on-disk location.
    pub fn path(&self) -> &Path {
        &self.path
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("start_sim_journal_{}_{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn sample_metrics(x: f64) -> RunMetrics {
        RunMetrics {
            exec_times: vec![x, x * 2.0],
            completion_times: vec![x + 0.1],
            jobs_done: 1,
            tasks_done: 2,
            ..RunMetrics::default()
        }
    }

    #[test]
    fn digest_is_stable_and_config_sensitive() {
        let cfg = SimConfig::test_defaults();
        assert_eq!(cfg_digest(&cfg), cfg_digest(&cfg.clone()));
        let mut other = cfg.clone();
        other.seed += 1;
        assert_ne!(cfg_digest(&cfg), cfg_digest(&other));
        let mut other = cfg.clone();
        other.fault_rate += 0.125;
        assert_ne!(cfg_digest(&cfg), cfg_digest(&other));
    }

    #[test]
    fn append_then_load_round_trips() {
        let dir = tmp_dir("round_trip");
        let path = dir.join("results.jsonl");
        let m1 = sample_metrics(1.5);
        let m2 = sample_metrics(0.1 + 0.2);
        {
            let mut j = Journal::open(&path, false).unwrap();
            j.append("a|X|1", "00ff", 1, &m1).unwrap();
            j.append("b|Y|2", "abcd", 3, &m2).unwrap();
        }
        let map = load_map(&path).unwrap();
        assert_eq!(map.len(), 2);
        let got = &map[&("a|X|1".to_string(), "00ff".to_string())];
        assert!(m1.diff_deterministic(got).is_none());
        let got = &map[&("b|Y|2".to_string(), "abcd".to_string())];
        assert!(m2.diff_deterministic(got).is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_final_line_is_skipped_and_later_records_win() {
        let dir = tmp_dir("torn");
        let path = dir.join("results.jsonl");
        {
            let mut j = Journal::open(&path, false).unwrap();
            j.append("cell", "1111", 1, &sample_metrics(1.0)).unwrap();
            j.append("cell", "1111", 2, &sample_metrics(9.0)).unwrap();
        }
        // Simulate a crash mid-append: a torn partial record at the tail.
        {
            use std::io::Write as _;
            let mut f =
                std::fs::OpenOptions::new().append(true).open(&path).unwrap();
            write!(f, "{{\"cell\":\"torn\",\"cfg\":\"22").unwrap();
        }
        let map = load_map(&path).unwrap();
        assert_eq!(map.len(), 1, "torn record must be ignored");
        // Later record for the same key wins.
        let got = &map[&("cell".to_string(), "1111".to_string())];
        assert!(sample_metrics(9.0).diff_deterministic(got).is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn compact_keeps_last_record_per_key_and_drops_torn_lines() {
        let dir = tmp_dir("compact");
        let path = dir.join("results.jsonl");
        {
            let mut j = Journal::open(&path, false).unwrap();
            j.append("cell", "1111", 1, &sample_metrics(1.0)).unwrap();
            j.append("other", "2222", 1, &sample_metrics(3.0)).unwrap();
            j.append("cell", "1111", 2, &sample_metrics(9.0)).unwrap();
        }
        {
            use std::io::Write as _;
            let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
            write!(f, "{{\"cell\":\"torn\",\"cfg\":\"33").unwrap();
        }
        let before = load_map(&path).unwrap();
        let (kept, dropped) = compact(&path).unwrap();
        assert_eq!((kept, dropped), (2, 2), "1 superseded + 1 torn line dropped");
        let after = load_map(&path).unwrap();
        // The resume view is unchanged by compaction.
        assert_eq!(after.len(), before.len());
        for (key, m) in &before {
            assert!(m.diff_deterministic(&after[key]).is_none(), "{key:?}");
        }
        // Surviving lines are byte-identical (first-appearance key order).
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].contains("\"cell\":\"cell\""));
        assert!(lines[1].contains("\"cell\":\"other\""));
        // Idempotent: a second compaction drops nothing.
        assert_eq!(compact(&path).unwrap(), (2, 0));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn compact_missing_journal_is_a_noop() {
        let dir = tmp_dir("compact_missing");
        assert_eq!(compact(&dir.join("absent.jsonl")).unwrap(), (0, 0));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_journal_is_empty_and_resume_appends() {
        let dir = tmp_dir("resume");
        let path = dir.join("results.jsonl");
        assert!(load_map(&path).unwrap().is_empty());
        {
            let mut j = Journal::open(&path, false).unwrap();
            j.append("a", "01", 1, &sample_metrics(1.0)).unwrap();
        }
        {
            // append=true keeps the prior record.
            let mut j = Journal::open(&path, true).unwrap();
            j.append("b", "02", 1, &sample_metrics(2.0)).unwrap();
        }
        assert_eq!(load_map(&path).unwrap().len(), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
