//! VM scheduling policies (paper §4.5).
//!
//! Every straggler technique runs on top of the same scheduler, as in the
//! paper.  The default is `A3cScheduler`, an online actor-critic surrogate
//! of the A3C-R2N2 policy [32] (see DESIGN.md §5): a linear-feature
//! softmax policy over candidate VMs trained by policy gradient against a
//! TD(0) critic, rewarded with negative normalized response time.  Random
//! placement (used to diversify training data in §4.4), round-robin and
//! min-min are also provided.
//!
//! All policies draw candidates from `World::available_vms` — the
//! availability index (DESIGN.md §9) — and score them with the world's
//! O(1) per-host load aggregates, so a `pick` costs O(available) (or
//! O(log available) for round-robin) instead of rescanning every VM and
//! every resident task on each candidate's host.

use crate::config::SchedulerKind;
use crate::sim::types::*;
use crate::sim::world::World;
use crate::util::rng::Pcg;
use std::collections::{HashMap, VecDeque};

/// Placement policy interface.
pub trait Scheduler: Send {
    fn name(&self) -> &'static str;
    /// Choose a VM for a pending task; None if nothing is placeable.
    fn pick(&mut self, w: &World, task: TaskId) -> Option<VmId>;
    /// Response-time feedback for the placement of `task` (lower = better).
    fn feedback(&mut self, _w: &World, _task: TaskId, _response_norm: f64) {}
}

/// Instantiate by config kind.
pub fn build(kind: SchedulerKind, rng: Pcg) -> Box<dyn Scheduler> {
    match kind {
        SchedulerKind::Random => Box::new(RandomScheduler { rng }),
        SchedulerKind::RoundRobin => Box::new(RoundRobin { next: 0 }),
        SchedulerKind::MinMin => Box::new(MinMin),
        SchedulerKind::A3c => Box::new(A3cScheduler::new(rng)),
    }
}

// ---------------------------------------------------------------- Random

/// Uniform random placement over available VMs: one index into the
/// availability slice, no candidate Vec.
pub struct RandomScheduler {
    rng: Pcg,
}

impl Scheduler for RandomScheduler {
    fn name(&self) -> &'static str {
        "random"
    }

    fn pick(&mut self, w: &World, _task: TaskId) -> Option<VmId> {
        let candidates = w.available_vms();
        if candidates.is_empty() {
            None
        } else {
            Some(candidates[self.rng.below(candidates.len())])
        }
    }
}

// ------------------------------------------------------------ RoundRobin

/// Cycles through VMs, skipping unavailable ones.
pub struct RoundRobin {
    next: usize,
}

impl Scheduler for RoundRobin {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn pick(&mut self, w: &World, _task: TaskId) -> Option<VmId> {
        // The availability slice is ascending, so the cyclic scan from
        // `next` collapses to one binary search: first available VM with
        // id >= next, wrapping to the smallest available id.
        let avail = w.available_vms();
        if avail.is_empty() {
            return None;
        }
        let i = avail.partition_point(|&v| v.raw() < self.next);
        let v = if i < avail.len() { avail[i] } else { avail[0] };
        self.next = v.raw() + 1;
        Some(v)
    }
}

// ---------------------------------------------------------------- MinMin

/// Min-min heuristic: place on the VM minimizing projected completion
/// time (queue depth + demand fit).
pub struct MinMin;

impl Scheduler for MinMin {
    fn name(&self) -> &'static str {
        "min-min"
    }

    fn pick(&mut self, w: &World, task: TaskId) -> Option<VmId> {
        let demand = w.task(task).demand.mips;
        let mut best: Option<(f64, VmId)> = None;
        for &v in w.available_vms().iter() {
            let vm = &w.vms[v];
            let n_tasks = vm.tasks.len() as f64;
            let share = vm.mips / (n_tasks + 1.0);
            let host_load = w.host_cpu_util(vm.host);
            let eta = w.task(task).remaining_mi / share.min(demand).max(1.0)
                * (1.0 + host_load);
            if best.map(|(b, _)| eta < b).unwrap_or(true) {
                best = Some((eta, v));
            }
        }
        best.map(|(_, v)| v)
    }
}

// ------------------------------------------------------------------ A3C

const N_FEAT: usize = 6;

/// Most pending-gradient entries retained; beyond this the oldest (by
/// first placement) are evicted — tasks that never report a response
/// (lost to kills/reruns) must not pin memory forever.
const MAX_PENDING: usize = 4096;

/// Online actor-critic surrogate of A3C-R2N2 [32].
///
/// Features per (task, VM) pair: host CPU util, VM queue depth, MIPS fit,
/// host straggler EMA, host RAM headroom, bias.  Actor: softmax over
/// candidate VMs with linear scores; critic: linear value baseline;
/// REINFORCE update with advantage (r − V).
pub struct A3cScheduler {
    rng: Pcg,
    /// Actor weights.
    w: [f64; N_FEAT],
    /// Critic weights.
    v: [f64; N_FEAT],
    lr: f64,
    /// Pending gradients keyed by task id for O(1) feedback lookup:
    /// (features of the chosen VM, mean features across candidates).
    /// A re-picked task (rerun/restart) overwrites its entry — feedback
    /// applies to the newest placement.
    pending: HashMap<TaskId, ([f64; N_FEAT], [f64; N_FEAT])>,
    /// Insertion order of first placement, driving FIFO eviction.  May
    /// hold ids already consumed by `feedback` (or re-picked); those are
    /// skipped lazily and compacted when the queue outgrows the map.
    pending_fifo: VecDeque<TaskId>,
    // Per-pick scratch buffers, reused across calls so a pick allocates
    // nothing in steady state.
    cand_buf: Vec<VmId>,
    feat_buf: Vec<[f64; N_FEAT]>,
    exp_buf: Vec<f64>,
}

impl A3cScheduler {
    pub fn new(rng: Pcg) -> Self {
        Self {
            rng,
            w: [0.0; N_FEAT],
            v: [0.0; N_FEAT],
            lr: 0.05,
            pending: HashMap::new(),
            pending_fifo: VecDeque::new(),
            cand_buf: Vec::new(),
            feat_buf: Vec::new(),
            exp_buf: Vec::new(),
        }
    }

    fn features(w: &World, task: TaskId, vm: VmId) -> [f64; N_FEAT] {
        let v = &w.vms[vm];
        let host = &w.hosts[v.host];
        let demand = w.task(task).demand.mips;
        let share = v.mips / (v.tasks.len() as f64 + 1.0);
        [
            w.host_cpu_util(v.host),
            (v.tasks.len() as f64 / 4.0).min(1.0),
            (share / demand.max(1.0)).min(2.0) / 2.0,
            host.straggler_ema,
            1.0 - w.host_ram_util(v.host),
            1.0,
        ]
    }

    fn score(&self, f: &[f64; N_FEAT]) -> f64 {
        // Prior: prefer low utilization / short queue / good fit even
        // before any learning signal arrives.
        let prior = -1.5 * f[0] - 1.0 * f[1] + 1.0 * f[2] - 1.0 * f[3];
        prior + self.w.iter().zip(f).map(|(w, x)| w * x).sum::<f64>()
    }
}

impl Scheduler for A3cScheduler {
    fn name(&self) -> &'static str {
        "a3c-r2n2"
    }

    fn pick(&mut self, w: &World, task: TaskId) -> Option<VmId> {
        // Sample up to 32 candidates to bound per-decision cost.  The
        // candidate list is copied into a reused scratch buffer (the RNG
        // shuffle needs ownership); features and softmax terms likewise
        // reuse their buffers, so steady-state picks allocate nothing.
        self.cand_buf.clear();
        self.cand_buf.extend_from_slice(&w.available_vms());
        if self.cand_buf.is_empty() {
            return None;
        }
        if self.cand_buf.len() > 32 {
            self.rng.shuffle(&mut self.cand_buf);
            self.cand_buf.truncate(32);
        }
        self.feat_buf.clear();
        for &v in &self.cand_buf {
            self.feat_buf.push(Self::features(w, task, v));
        }
        // Scores are written into the exp buffer, then exponentiated in
        // place once the max is known (same arithmetic as two passes).
        self.exp_buf.clear();
        for f in &self.feat_buf {
            self.exp_buf.push(self.score(f));
        }
        let max = self.exp_buf.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        for s in self.exp_buf.iter_mut() {
            *s = (*s - max).exp();
        }
        let total: f64 = self.exp_buf.iter().sum();
        let mut pick = self.rng.f64() * total;
        let mut chosen = self.cand_buf.len() - 1;
        for (i, e) in self.exp_buf.iter().enumerate() {
            pick -= e;
            if pick <= 0.0 {
                chosen = i;
                break;
            }
        }
        // Mean features = softmax-expected gradient baseline term.
        let mut mean = [0.0; N_FEAT];
        for (f, e) in self.feat_buf.iter().zip(&self.exp_buf) {
            for k in 0..N_FEAT {
                mean[k] += f[k] * e / total;
            }
        }
        self.pending.insert(task, (self.feat_buf[chosen], mean));
        self.pending_fifo.push_back(task);
        while self.pending.len() > MAX_PENDING {
            let Some(old) = self.pending_fifo.pop_front() else { break };
            self.pending.remove(&old);
        }
        if self.pending_fifo.len() > 2 * MAX_PENDING {
            // Compact ids already consumed by feedback / overwritten picks.
            let live = &self.pending;
            self.pending_fifo.retain(|t| live.contains_key(t));
        }
        Some(self.cand_buf[chosen])
    }

    fn feedback(&mut self, _w: &World, task: TaskId, response_norm: f64) {
        let Some((chosen, mean)) = self.pending.remove(&task) else {
            return;
        };
        let reward = -response_norm.min(10.0);
        let value: f64 = self.v.iter().zip(&chosen).map(|(v, x)| v * x).sum();
        let advantage = reward - value;
        for k in 0..N_FEAT {
            // Policy gradient: ∇ log π = f_chosen − E_π[f].
            self.w[k] += self.lr * advantage * (chosen[k] - mean[k]);
            // TD(0) critic toward reward.
            self.v[k] += self.lr * advantage * chosen[k];
            self.w[k] = self.w[k].clamp(-10.0, 10.0);
            self.v[k] = self.v[k].clamp(-10.0, 10.0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::sim::types::{Task, TaskDemand, TaskState};

    fn world_with_pending_task() -> (World, TaskId) {
        let mut w = World::new(&SimConfig::test_defaults());
        let id = TaskId::new(0);
        w.add_task(Task {
            id,
            job: JobId::new(0),
            length_mi: 1000.0,
            demand: TaskDemand { mips: 150.0, ram_gb: 0.2, disk_gb: 0.5, bw_kbps: 0.1 },
            state: TaskState::Pending,
            vm: None,
            last_vm: None,
            remaining_mi: 1000.0,
            submit_t: 0.0,
            first_start_t: None,
            restart_time: 0.0,
            restarts: 0,
            slowdown: 1.0,
            speculative_of: None,
            mitigated: false,
        });
        (w, id)
    }

    #[test]
    fn all_schedulers_place_on_idle_fleet() {
        let (w, t) = world_with_pending_task();
        for kind in [
            SchedulerKind::Random,
            SchedulerKind::RoundRobin,
            SchedulerKind::MinMin,
            SchedulerKind::A3c,
        ] {
            let mut s = build(kind, Pcg::seeded(1));
            let vm = s.pick(&w, t);
            assert!(vm.is_some(), "{} failed to place", s.name());
        }
    }

    #[test]
    fn no_scheduler_places_on_down_fleet() {
        let (mut w, t) = world_with_pending_task();
        for h in 0..w.hosts.len() {
            w.set_host_down(HostId::new(h), 1e12);
        }
        for kind in [
            SchedulerKind::Random,
            SchedulerKind::RoundRobin,
            SchedulerKind::MinMin,
            SchedulerKind::A3c,
        ] {
            let mut s = build(kind, Pcg::seeded(1));
            assert!(s.pick(&w, t).is_none(), "{} placed on down fleet", s.name());
        }
    }

    #[test]
    fn round_robin_cycles() {
        let (w, t) = world_with_pending_task();
        let mut s = RoundRobin { next: 0 };
        let a = s.pick(&w, t).unwrap();
        let b = s.pick(&w, t).unwrap();
        assert_ne!(a, b);
    }

    #[test]
    fn minmin_prefers_empty_vm() {
        let (mut w, t) = world_with_pending_task();
        // Fill VM 0 with work.
        let clone = w.task(t).clone();
        let t2 = TaskId::new(w.n_tasks());
        w.add_task(Task { id: t2, ..clone });
        w.start_task(t2, VmId::new(0), 1.0);
        let mut s = MinMin;
        let vm = s.pick(&w, t).unwrap();
        assert_ne!(vm, VmId::new(0));
    }

    #[test]
    fn a3c_learns_to_avoid_straggler_hosts() {
        let (mut w, t) = world_with_pending_task();
        // Mark host 0 as a straggler factory.
        w.hosts[HostId::new(0)].straggler_ema = 1.0;
        let mut s = A3cScheduler::new(Pcg::seeded(3));
        // Train: placements on host 0 get terrible reward.
        for _ in 0..300 {
            let vm = s.pick(&w, t).unwrap();
            let bad = w.vms[vm].host == HostId::new(0);
            s.feedback(&w, t, if bad { 8.0 } else { 1.0 });
        }
        let picks_on_bad = (0..100)
            .filter(|_| {
                let vm = s.pick(&w, t).unwrap();
                s.pending.clear();
                w.vms[vm].host == HostId::new(0)
            })
            .count();
        // Host 0 has 4/9 of the VMs in the test fleet; learning should
        // push selection well below that share.
        assert!(picks_on_bad < 25, "picked bad host {picks_on_bad}/100");
    }
}
