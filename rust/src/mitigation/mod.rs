//! Straggler mitigation engine (paper §3.3, Algorithm 1 lines 14–19).
//!
//! Two strategies:
//! * **Speculation** — launch a copy of the task on a different node and
//!   take whichever result arrives first (deadline-driven jobs).
//! * **Re-run** — kill the task and restart it fresh on a different node
//!   (non-deadline jobs; one copy at a time saves energy).
//!
//! Target nodes are chosen as the serviceable VM on the host with the
//! lowest moving average of straggler counts (Alg. 1 / §3.3), excluding
//! the task's current host.  All state changes go through the `World`
//! registry so the incremental indexes (clone map, pending/running sets)
//! stay consistent — see DESIGN.md §3.

use crate::sim::types::*;
use crate::sim::world::World;

/// A mitigation decision produced by a straggler manager.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Action {
    /// Run a copy elsewhere; first finisher wins.
    Speculate(TaskId),
    /// Kill + restart elsewhere.
    Rerun(TaskId),
    /// Delay a not-yet-started task until `t` (Wrangler).
    Hold(TaskId, f64),
}

/// Outcome counters for metrics.
#[derive(Clone, Copy, Debug, Default)]
pub struct MitigationStats {
    pub speculations: u64,
    pub reruns: u64,
    pub holds: u64,
    pub skipped: u64,
}

/// Launch a speculative copy of `task`.  Returns the clone's id, or None
/// if no target VM exists or the task is no longer running.
pub fn speculate(w: &mut World, task: TaskId, slowdown: f64) -> Option<TaskId> {
    if !w.task(task).is_running() || w.task(task).speculative_of.is_some() {
        return None;
    }
    // A task races at most one live clone at a time.
    if find_clone(w, task).is_some() {
        return None;
    }
    let exclude = w.task(task).vm.map(|v| w.vms[v].host);
    let target = w.best_mitigation_vm(exclude)?;
    let orig = w.task(task);
    let clone_id = TaskId::new(w.n_tasks());
    let clone = Task {
        id: clone_id,
        job: orig.job,
        length_mi: orig.length_mi,
        demand: orig.demand,
        state: TaskState::Pending,
        vm: None,
        last_vm: None,
        remaining_mi: orig.length_mi,
        submit_t: w.now,
        first_start_t: None,
        restart_time: 0.0,
        restarts: 0,
        slowdown: 1.0,
        speculative_of: Some(task),
        mitigated: true,
    };
    w.add_task(clone);
    w.mark_mitigated(task);
    w.start_task(clone_id, target, slowdown);
    Some(clone_id)
}

/// Kill `task` and restart it on a different node.  Returns the target VM.
pub fn rerun(w: &mut World, task: TaskId, slowdown: f64, restart_penalty_s: f64) -> Option<VmId> {
    if !w.task(task).is_running() {
        return None;
    }
    let exclude = w.task(task).vm.map(|v| w.vms[v].host);
    let target = w.best_mitigation_vm(exclude)?;
    w.reset_task(task, restart_penalty_s);
    w.mark_mitigated(task);
    w.start_task(task, target, slowdown);
    Some(target)
}

/// Put a pending task on hold until `t` (Wrangler-style delaying).
pub fn hold(w: &mut World, task: TaskId, until: f64) -> bool {
    if w.hold_task(task, until) {
        w.mark_mitigated(task);
        true
    } else {
        false
    }
}

/// Release held tasks whose hold expired (back to Pending for placement).
pub fn release_held(w: &mut World) -> usize {
    w.release_expired_holds()
}

/// The live speculative clone of `task`, if any (O(1) via the registry's
/// clone map; the pre-index engine scanned every task ever created).
pub fn find_clone(w: &World, task: TaskId) -> Option<TaskId> {
    w.clone_of(task)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;

    fn world_with_running_task() -> (World, TaskId) {
        let mut w = World::new(&SimConfig::test_defaults());
        let id = TaskId::new(0);
        w.add_task(Task {
            id,
            job: JobId::new(0),
            length_mi: 1000.0,
            demand: TaskDemand { mips: 100.0, ram_gb: 0.2, disk_gb: 0.5, bw_kbps: 0.1 },
            state: TaskState::Pending,
            vm: None,
            last_vm: None,
            remaining_mi: 1000.0,
            submit_t: 0.0,
            first_start_t: None,
            restart_time: 0.0,
            restarts: 0,
            slowdown: 1.0,
            speculative_of: None,
            mitigated: false,
        });
        w.start_task(id, VmId::new(0), 4.0); // slow original
        (w, id)
    }

    #[test]
    fn speculate_creates_racing_clone_on_other_host() {
        let (mut w, t) = world_with_running_task();
        let clone = speculate(&mut w, t, 1.0).unwrap();
        assert_eq!(w.task(clone).speculative_of, Some(t));
        assert!(w.task(clone).is_running());
        let (h1, h2) =
            (w.vms[w.task(t).vm.unwrap()].host, w.vms[w.task(clone).vm.unwrap()].host);
        assert_ne!(h1, h2, "clone must land on a different host");
        assert!(w.task(t).mitigated);
        // Second speculation on the same task is refused.
        assert!(speculate(&mut w, t, 1.0).is_none());
        assert_eq!(find_clone(&w, t), Some(clone));
        w.assert_consistent();
    }

    #[test]
    fn clone_outruns_slow_original() {
        let (mut w, t) = world_with_running_task();
        let clone = speculate(&mut w, t, 1.0).unwrap();
        // original: rate 100/4 = 25 → eta 40 s; clone: 100 → eta 10 s.
        let eta = w.next_finish_time().unwrap();
        let done = w.advance(eta);
        assert_eq!(done, vec![clone]);
    }

    #[test]
    fn rerun_moves_and_resets() {
        let (mut w, t) = world_with_running_task();
        w.advance(4.0);
        let old_vm = w.task(t).vm.unwrap();
        let new_vm = rerun(&mut w, t, 1.0, 30.0).unwrap();
        assert_ne!(w.vms[new_vm].host, w.vms[old_vm].host);
        assert_eq!(w.task(t).remaining_mi, 1000.0);
        assert_eq!(w.task(t).restarts, 1);
        assert!(w.task(t).is_running());
        w.assert_consistent();
    }

    #[test]
    fn hold_and_release() {
        let mut w = World::new(&SimConfig::test_defaults());
        let id = TaskId::new(0);
        w.add_task(Task {
            id,
            job: JobId::new(0),
            length_mi: 100.0,
            demand: TaskDemand::default(),
            state: TaskState::Pending,
            vm: None,
            last_vm: None,
            remaining_mi: 100.0,
            submit_t: 0.0,
            first_start_t: None,
            restart_time: 0.0,
            restarts: 0,
            slowdown: 1.0,
            speculative_of: None,
            mitigated: false,
        });
        assert!(hold(&mut w, id, 50.0));
        assert_eq!(release_held(&mut w), 0);
        w.advance(50.0);
        assert_eq!(release_held(&mut w), 1);
        assert_eq!(w.task(id).state, TaskState::Pending);
        w.assert_consistent();
    }

    #[test]
    fn mitigation_refused_for_non_running() {
        let mut w = World::new(&SimConfig::test_defaults());
        w.add_task(Task {
            id: TaskId::new(0),
            job: JobId::new(0),
            length_mi: 100.0,
            demand: TaskDemand::default(),
            state: TaskState::Completed { t: 1.0 },
            vm: None,
            last_vm: None,
            remaining_mi: 0.0,
            submit_t: 0.0,
            first_start_t: Some(0.0),
            restart_time: 0.0,
            restarts: 0,
            slowdown: 1.0,
            speculative_of: None,
            mitigated: false,
        });
        assert!(speculate(&mut w, TaskId::new(0), 1.0).is_none());
        assert!(rerun(&mut w, TaskId::new(0), 1.0, 0.0).is_none());
        assert!(!hold(&mut w, TaskId::new(0), 10.0));
    }
}
