//! SGC [9]: Stochastic Gradient Coding — approximate redundancy via a
//! pair-wise balanced scheme.
//!
//! In SGC each data point is shared with a partner worker so the
//! aggregator tolerates stragglers without waiting.  Mapped onto
//! bag-of-tasks execution: tasks are paired (i, i+1) within a job and one
//! member of every pair receives a redundant copy up-front — static,
//! distribution-agnostic redundancy, which is exactly why SGC burns more
//! resources at equal mitigation quality in the paper's figures.

use crate::mitigation::Action;
use crate::predictor::FeatureExtractor;
use crate::sim::engine::Manager;
use crate::sim::world::World;

pub struct SgcManager {
    /// Redundancy ratio: fraction of each job's tasks receiving a clone.
    pub redundancy: f64,
}

impl SgcManager {
    pub fn new() -> Self {
        Self { redundancy: 0.5 }
    }
}

impl Default for SgcManager {
    fn default() -> Self {
        Self::new()
    }
}

impl Manager for SgcManager {
    fn name(&self) -> &'static str {
        "SGC"
    }

    fn on_interval(&mut self, w: &World, _fx: &FeatureExtractor) -> Vec<Action> {
        let mut actions = Vec::new();
        for &jid in w.active_jobs().iter() {
            let job = w.job(jid);
            let clones_target = (job.tasks.len() as f64 * self.redundancy).round() as usize;
            let mut cloned = job
                .tasks
                .iter()
                .filter(|&&t| w.task(t).mitigated)
                .count();
            // Pair-wise balance: clone the first member of each (2i, 2i+1)
            // pair, in order, until the redundancy target is met.
            for (idx, &t) in job.tasks.iter().enumerate() {
                if cloned >= clones_target {
                    break;
                }
                if idx % 2 != 0 {
                    continue;
                }
                let task = w.task(t);
                if task.is_running() && task.speculative_of.is_none() && !task.mitigated {
                    actions.push(Action::Speculate(t));
                    cloned += 1;
                }
            }
        }
        actions
    }
}
