//! Baseline straggler-management techniques (paper §4.6, Table 1).
//!
//! Every baseline implements `sim::Manager` and runs on the same
//! scheduler and simulator as START, as in the paper's methodology.
//! A shared `JobTracker` provides the observable signals reactive
//! techniques use (sibling response statistics, progress rates).

pub mod dolly;
pub mod grass;
pub mod igru_sd;
pub mod late;
pub mod nearestfit;
pub mod rpps_manager;
pub mod sgc;
pub mod wrangler;

pub use dolly::DollyManager;
pub use grass::GrassManager;
pub use igru_sd::IgruSdManager;
pub use late::LateManager;
pub use nearestfit::NearestFitManager;
pub use rpps_manager::RppsManager;
pub use sgc::SgcManager;
pub use wrangler::WranglerManager;

use crate::sim::types::*;
use crate::sim::world::World;

/// Observable per-job statistics for reactive detection (no access to
/// ground-truth Pareto parameters).
pub struct SiblingStats {
    /// Completed siblings' response times (seconds).
    pub completed: Vec<f64>,
    pub median: f64,
}

/// Response statistics of a job's completed tasks.
pub fn sibling_stats(w: &World, job: JobId) -> SiblingStats {
    let mut completed: Vec<f64> = w
        .job(job)
        .tasks
        .iter()
        .filter_map(|&t| {
            let task = w.task(t);
            match task.state {
                TaskState::Completed { t: tc } => Some(tc - task.submit_t),
                _ => None,
            }
        })
        .collect();
    completed.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = if completed.is_empty() {
        0.0
    } else {
        completed[completed.len() / 2]
    };
    SiblingStats { completed, median }
}

/// Elapsed time of a running task.
pub fn elapsed(w: &World, task: TaskId) -> f64 {
    w.now - w.task(task).submit_t
}

/// Capability flags (Table 1) — asserted in tests so the qualitative
/// comparison table stays truthful in code.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Capabilities {
    pub detection: bool,
    pub mitigation: bool,
    pub proactive: bool,
    pub prediction: bool,
    pub dynamic: bool,
    pub heterogeneous: bool,
}

/// Table 1, one row per technique.
pub fn capabilities(name: &str) -> Capabilities {
    match name {
        "START" => Capabilities {
            detection: true,
            mitigation: true,
            proactive: true,
            prediction: true,
            dynamic: true,
            heterogeneous: true,
        },
        "IGRU-SD" => Capabilities {
            detection: true,
            mitigation: true,
            proactive: true,
            prediction: true,
            dynamic: true,
            heterogeneous: false,
        },
        "SGC" => Capabilities {
            detection: true,
            mitigation: true,
            proactive: true,
            prediction: true,
            dynamic: true,
            heterogeneous: false,
        },
        "Wrangler" => Capabilities {
            detection: false,
            mitigation: true,
            proactive: true,
            prediction: false,
            dynamic: true,
            heterogeneous: false,
        },
        "GRASS" => Capabilities {
            detection: false,
            mitigation: true,
            proactive: true,
            prediction: false,
            dynamic: false,
            heterogeneous: false,
        },
        "Dolly" => Capabilities {
            detection: false,
            mitigation: true,
            proactive: true,
            prediction: false,
            dynamic: false,
            heterogeneous: true,
        },
        "NearestFit" => Capabilities {
            detection: true,
            mitigation: false,
            proactive: false,
            prediction: false,
            dynamic: true,
            heterogeneous: false,
        },
        "LATE" => Capabilities {
            detection: false,
            mitigation: true,
            proactive: true,
            prediction: false,
            dynamic: false,
            heterogeneous: true,
        },
        _ => Capabilities {
            detection: false,
            mitigation: false,
            proactive: false,
            prediction: false,
            dynamic: false,
            heterogeneous: false,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_start_dominates() {
        let start = capabilities("START");
        for other in ["IGRU-SD", "SGC", "Wrangler", "GRASS", "Dolly", "NearestFit", "LATE"] {
            let c = capabilities(other);
            // START has every capability any baseline has (Table 1).
            assert!(start.detection >= c.detection, "{other}");
            assert!(start.mitigation >= c.mitigation, "{other}");
            assert!(start.proactive >= c.proactive, "{other}");
            assert!(start.prediction >= c.prediction, "{other}");
            assert!(start.dynamic >= c.dynamic, "{other}");
            assert!(start.heterogeneous >= c.heterogeneous, "{other}");
        }
    }

    #[test]
    fn only_prediction_methods_predict() {
        assert!(capabilities("START").prediction);
        assert!(capabilities("IGRU-SD").prediction);
        assert!(!capabilities("GRASS").prediction);
        assert!(!capabilities("Dolly").prediction);
    }
}
