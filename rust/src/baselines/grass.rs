//! GRASS [8]: Greedy and Resource-Aware Speculative Scheduling.
//!
//! Reactive speculation: once a job has completed siblings, any running
//! task whose elapsed time exceeds `spec_factor ×` the sibling median is
//! greedily speculated, deadline-bound jobs first, subject to a
//! resource-aware cap on concurrent speculative copies (a fraction of
//! idle VMs).

use crate::baselines::{elapsed, sibling_stats};
use crate::mitigation::Action;
use crate::predictor::FeatureExtractor;
use crate::sim::engine::Manager;
use crate::sim::types::*;
use crate::sim::world::World;

pub struct GrassManager {
    /// Speculate when elapsed > factor × sibling median.
    pub spec_factor: f64,
    /// Max live clones as a fraction of total VMs.
    pub budget_frac: f64,
}

impl GrassManager {
    pub fn new() -> Self {
        Self { spec_factor: 1.5, budget_frac: 0.1 }
    }

    fn live_clones(w: &World) -> usize {
        w.live_clone_count()
    }
}

impl Default for GrassManager {
    fn default() -> Self {
        Self::new()
    }
}

impl Manager for GrassManager {
    fn name(&self) -> &'static str {
        "GRASS"
    }

    fn on_interval(&mut self, w: &World, _fx: &FeatureExtractor) -> Vec<Action> {
        let budget = ((w.vms.len() as f64 * self.budget_frac) as usize)
            .saturating_sub(Self::live_clones(w));
        if budget == 0 {
            return Vec::new();
        }
        // Candidate slow tasks: (deadline priority, slowness) ordered.
        let mut candidates: Vec<(bool, f64, TaskId)> = Vec::new();
        for &jid in w.active_jobs().iter() {
            let job = w.job(jid);
            let stats = sibling_stats(w, job.id);
            if stats.completed.is_empty() {
                continue; // greedy: needs an observed baseline first
            }
            for &t in &job.tasks {
                let task = w.task(t);
                if task.is_running() && task.speculative_of.is_none() && !task.mitigated {
                    let e = elapsed(w, t);
                    if e > self.spec_factor * stats.median {
                        candidates.push((job.deadline_driven, e / stats.median.max(1e-9), t));
                    }
                }
            }
        }
        // Deadline-bound jobs first, then slowest (greedy order).
        candidates.sort_by(|a, b| {
            b.0.cmp(&a.0).then(b.1.partial_cmp(&a.1).unwrap_or(std::cmp::Ordering::Equal))
        });
        candidates.into_iter().take(budget).map(|(_, _, t)| Action::Speculate(t)).collect()
    }
}
