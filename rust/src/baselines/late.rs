//! LATE [29]: Longest Approximate Time to End.
//!
//! Estimates each running task's time-to-end from its progress rate and
//! speculatively executes a copy of the slowest task per job (the one
//! with the longest ETA) on a fast node, provided its ETA clearly exceeds
//! its siblings' (threshold factor) and a speculation cap is respected.

use crate::mitigation::Action;
use crate::predictor::FeatureExtractor;
use crate::sim::engine::Manager;
use crate::sim::types::*;
use crate::sim::world::World;

pub struct LateManager {
    pub factor: f64,
    /// Cap on live speculative copies (fraction of VMs).
    pub budget_frac: f64,
}

impl LateManager {
    pub fn new() -> Self {
        Self { factor: 1.5, budget_frac: 0.1 }
    }

    /// ETA from observed progress: elapsed / progress − elapsed.
    fn eta(w: &World, task: TaskId) -> Option<f64> {
        let t = w.task(task);
        let started = t.first_start_t?;
        let elapsed = w.now - started;
        let p = t.progress();
        if p < 0.01 || elapsed <= 0.0 {
            return None;
        }
        Some(elapsed / p - elapsed)
    }
}

impl Default for LateManager {
    fn default() -> Self {
        Self::new()
    }
}

impl Manager for LateManager {
    fn name(&self) -> &'static str {
        "LATE"
    }

    fn on_interval(&mut self, w: &World, _fx: &FeatureExtractor) -> Vec<Action> {
        let live_clones = w.live_clone_count();
        let mut budget =
            ((w.vms.len() as f64 * self.budget_frac) as usize).saturating_sub(live_clones);
        let mut actions = Vec::new();
        for &jid in w.active_jobs().iter() {
            let job = w.job(jid);
            if budget == 0 {
                break;
            }
            // ETA of each running task; speculate the longest if it is
            // `factor ×` above the job median ETA.
            let mut etas: Vec<(f64, TaskId)> = job
                .tasks
                .iter()
                .filter_map(|&t| {
                    let task = w.task(t);
                    if task.is_running() && task.speculative_of.is_none() && !task.mitigated {
                        Self::eta(w, t).map(|e| (e, t))
                    } else {
                        None
                    }
                })
                .collect();
            if etas.len() < 2 {
                continue;
            }
            etas.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            let median = etas[etas.len() / 2].0;
            let (worst_eta, worst) = *etas.last().unwrap();
            if worst_eta > self.factor * median.max(1.0) {
                actions.push(Action::Speculate(worst));
                budget -= 1;
            }
        }
        actions
    }
}
