//! RPPS manager [23]: ARIMA workload forecasting + the shared mitigation
//! strategy.  The paper compares RPPS only on prediction accuracy
//! (Fig. 9); wiring it as a full manager also lets it participate in
//! ablations.

use crate::mitigation::Action;
use crate::predictor::{FeatureExtractor, RppsPredictor};
use crate::sim::engine::Manager;
use crate::sim::types::*;
use crate::sim::world::World;
use std::collections::HashMap;

pub struct RppsManager {
    pub predictor: RppsPredictor,
    final_predictions: HashMap<JobId, f64>,
}

impl RppsManager {
    pub fn new() -> Self {
        Self { predictor: RppsPredictor::new(), final_predictions: HashMap::new() }
    }
}

impl Default for RppsManager {
    fn default() -> Self {
        Self::new()
    }
}

impl Manager for RppsManager {
    fn name(&self) -> &'static str {
        "RPPS"
    }

    fn on_interval(&mut self, w: &World, _fx: &FeatureExtractor) -> Vec<Action> {
        self.predictor.observe(w);
        let mut actions = Vec::new();
        for &job in w.active_jobs().iter() {
            let es = self.predictor.expected_stragglers(w, job);
            self.final_predictions.insert(job, es);
            let q = w.job(job).tasks.len();
            let done = w.completed_tasks(job);
            let es_round = es.round() as usize;
            let endgame = es_round > 0 && done + es_round >= q;
            let stats = crate::baselines::sibling_stats(w, job);
            for &t in &w.job(job).tasks {
                let task = w.task(t);
                if !task.is_running() || task.speculative_of.is_some() || task.mitigated {
                    continue;
                }
                let reactive = !stats.completed.is_empty()
                    && (w.now - task.submit_t) > 1.5 * stats.median;
                if !(endgame && reactive) {
                    continue;
                }
                actions.push(if w.job(job).deadline_driven || task.progress() > 0.5 {
                    Action::Speculate(t)
                } else {
                    Action::Rerun(t)
                });
            }
        }
        actions
    }

    fn predicted_stragglers(&mut self, job: JobId) -> Option<f64> {
        self.final_predictions.remove(&job)
    }
}
