//! Wrangler [17]: proactive straggler avoidance via a linear model with
//! confidence bounds on node utilization counters.
//!
//! A recursive-least-squares linear model (ml::linreg) maps host
//! utilization features to an observable straggler indicator (task
//! response ≫ sibling median).  Before each placement the engine consults
//! `filter_placement`: if the model is confident (`uncertainty` below a
//! bound) that the target node will straggle, the task is delayed — the
//! paper's "delay the execution of tasks on nodes with straggler
//! confidence above a threshold".

use crate::mitigation::Action;
use crate::ml::OnlineLinReg;
use crate::predictor::FeatureExtractor;
use crate::sim::engine::Manager;
use crate::sim::types::*;
use crate::sim::world::World;

const N_FEAT: usize = 5;

pub struct WranglerManager {
    model: OnlineLinReg,
    /// Straggler-probability threshold above which placement is delayed.
    pub threshold: f64,
    /// Required confidence (max predictive uncertainty) to act.
    pub conf_bound: f64,
    /// Minimum observations before vetoing anything.
    pub warmup: u64,
    /// Per-interval cap on delays (avoid starving the queue).
    pub max_delays_per_interval: usize,
    delays_this_interval: usize,
}

impl WranglerManager {
    pub fn new() -> Self {
        Self {
            model: OnlineLinReg::new(N_FEAT, 1.0),
            threshold: 0.45,
            conf_bound: 0.5,
            warmup: 50,
            max_delays_per_interval: 16,
            delays_this_interval: 0,
        }
    }

    fn host_features(w: &World, host: HostId) -> [f64; N_FEAT] {
        [
            w.host_cpu_util(host),
            w.host_ram_util(host),
            w.host_bw_util(host),
            (w.host_task_count(host) as f64 / 16.0).min(1.0),
            1.0,
        ]
    }

    /// Observable straggler label: response > 1.5× sibling median.
    fn label(w: &World, task: TaskId, t_complete: f64) -> Option<f64> {
        let t = w.task(task);
        let stats = super::sibling_stats(w, t.job);
        if stats.completed.len() < 2 {
            return None;
        }
        Some(if (t_complete - t.submit_t) > 1.5 * stats.median { 1.0 } else { 0.0 })
    }
}

impl Default for WranglerManager {
    fn default() -> Self {
        Self::new()
    }
}

impl Manager for WranglerManager {
    fn name(&self) -> &'static str {
        "Wrangler"
    }

    fn on_interval(&mut self, _w: &World, _fx: &FeatureExtractor) -> Vec<Action> {
        self.delays_this_interval = 0;
        Vec::new() // Wrangler acts at placement time, not per interval.
    }

    fn on_task_complete(&mut self, w: &World, task: TaskId) {
        let Some(vm) = w.task(task).last_vm else { return };
        let host = w.vms[vm].host;
        if let Some(y) = Self::label(w, task, w.now) {
            self.model.update(&Self::host_features(w, host), y);
        }
    }

    fn filter_placement(&mut self, w: &World, _task: TaskId, vm: VmId) -> bool {
        if self.model.n() < self.warmup
            || self.delays_this_interval >= self.max_delays_per_interval
        {
            return true;
        }
        let x = Self::host_features(w, w.vms[vm].host);
        let pred = self.model.predict(&x);
        let unc = self.model.uncertainty(&x);
        if pred > self.threshold && unc < self.conf_bound {
            self.delays_this_interval += 1;
            false // delay: leave pending for a later interval
        } else {
            true
        }
    }
}
