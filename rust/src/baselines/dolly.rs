//! Dolly [20]: proactive cloning of *small* jobs within a resource budget.
//!
//! Dolly clones every task of a small job at launch (no waiting for
//! straggler evidence) and takes the first finisher, keeping the extra
//! resource consumption within a budget (the paper quotes ~5 % extra for
//! up to 46 % response-time gains on small jobs).  The number of clones is
//! chosen by an upper-confidence bound on observed per-host CPU headroom —
//! here: clone only when fleet CPU utilization UCB stays under a cap.

use crate::mitigation::Action;
use crate::predictor::FeatureExtractor;
use crate::sim::engine::Manager;
use crate::sim::types::*;
use crate::sim::world::World;
use crate::util::stats::Online;

pub struct DollyManager {
    /// Jobs with at most this many tasks are cloned.
    pub small_job_q: usize,
    /// Clone budget as a fraction of cumulative original tasks.
    pub budget_frac: f64,
    /// UCB cap on fleet CPU utilization for cloning to proceed.
    pub util_cap: f64,
    util_stats: Online,
    clones_launched: u64,
    tasks_seen: u64,
    marked: Vec<JobId>,
}

impl DollyManager {
    pub fn new() -> Self {
        Self {
            small_job_q: 4,
            budget_frac: 0.10,
            util_cap: 0.85,
            util_stats: Online::default(),
            clones_launched: 0,
            tasks_seen: 0,
            marked: Vec::new(),
        }
    }

    fn fleet_util(w: &World) -> f64 {
        let mut total = 0.0;
        let mut up = 0usize;
        for h in &w.hosts {
            if h.is_up(w.now) {
                total += w.host_cpu_util(h.id);
                up += 1;
            }
        }
        if up == 0 {
            1.0
        } else {
            total / up as f64
        }
    }
}

impl Default for DollyManager {
    fn default() -> Self {
        Self::new()
    }
}

impl Manager for DollyManager {
    fn name(&self) -> &'static str {
        "Dolly"
    }

    fn on_job_arrival(&mut self, w: &World, _fx: &FeatureExtractor, job: JobId) {
        self.tasks_seen += w.job(job).tasks.len() as u64;
        if w.job(job).tasks.len() <= self.small_job_q {
            self.marked.push(job);
        }
    }

    fn on_interval(&mut self, w: &World, _fx: &FeatureExtractor) -> Vec<Action> {
        let util = Self::fleet_util(w);
        self.util_stats.push(util);
        // UCB on utilization: mean + std; clone only with headroom.
        let ucb = self.util_stats.mean() + self.util_stats.std();
        if ucb > self.util_cap {
            return Vec::new();
        }
        let budget =
            ((self.tasks_seen as f64 * self.budget_frac) as u64).saturating_sub(self.clones_launched);
        if budget == 0 {
            return Vec::new();
        }
        let mut actions = Vec::new();
        self.marked.retain(|&job| w.job(job).is_active());
        for &job in &self.marked {
            for &t in &w.job(job).tasks {
                let task = w.task(t);
                // Clone right after launch (progress still near zero).
                if task.is_running()
                    && task.speculative_of.is_none()
                    && !task.mitigated
                    && task.progress() < 0.25
                {
                    actions.push(Action::Speculate(t));
                    if actions.len() as u64 >= budget {
                        self.clones_launched += actions.len() as u64;
                        return actions;
                    }
                }
            }
        }
        self.clones_launched += actions.len() as u64;
        actions
    }
}
