//! IGRU-SD manager [22]: the GRU resource-request predictor plus the same
//! re-run/speculation mitigation as START (paper §4.6: "we use the same
//! re-run and speculation strategy (based on deadline requirements) for
//! fair comparison").

use crate::mitigation::Action;
use crate::predictor::{FeatureExtractor, IgruPredictor};
use crate::sim::engine::Manager;
use crate::sim::trace::PredictSpans;
use crate::sim::types::*;
use crate::sim::world::World;
use std::collections::HashMap;
use std::time::Instant;

pub struct IgruSdManager {
    predictor: IgruPredictor,
    /// Latest E_S per active job.
    predictions: HashMap<JobId, f64>,
    /// Final prediction per job (kept for MAPE after completion).
    final_predictions: HashMap<JobId, f64>,
    /// Sub-span breakdown of the last `on_interval` (feature-extract /
    /// GRU dispatch / mitigation decision), drained by the engine into
    /// `PhaseProfile` — same instrumentation as `StartManager`, so the
    /// per-phase latency comparison covers both predictive techniques.
    spans: Option<PredictSpans>,
}

impl IgruSdManager {
    pub fn new(predictor: IgruPredictor) -> Self {
        Self {
            predictor,
            predictions: HashMap::new(),
            final_predictions: HashMap::new(),
            spans: None,
        }
    }
}

impl Manager for IgruSdManager {
    fn name(&self) -> &'static str {
        "IGRU-SD"
    }

    fn on_interval(&mut self, w: &World, fx: &FeatureExtractor) -> Vec<Action> {
        // Prediction and decision interleave per job here, so the decide
        // span is the interval total minus the predictor's own
        // feature/dispatch accumulators (drained at the end).
        let interval_start = Instant::now();
        let mut actions = Vec::new();
        for &job in w.active_jobs().iter() {
            let (es, _flagged) = match self.predictor.expected_stragglers(w, fx, job) {
                Ok(r) => r,
                Err(_) => continue,
            };
            self.predictions.insert(job, es);
            self.final_predictions.insert(job, es);
            // Same mitigation strategy as START (paper §4.6), but the
            // trigger works off IGRU-SD's demand forecasts + a reactive
            // sibling-median check — it has no per-job distribution, so
            // its detection remains later/noisier than START's.
            let q = w.job(job).tasks.len();
            let done = w.completed_tasks(job);
            let es_round = es.round() as usize;
            let endgame = es_round > 0 && done + es_round >= q;
            let stats = crate::baselines::sibling_stats(w, job);
            for &t in &w.job(job).tasks {
                let task = w.task(t);
                if !task.is_running() || task.speculative_of.is_some() || task.mitigated {
                    continue;
                }
                let reactive = !stats.completed.is_empty()
                    && (w.now - task.submit_t) > 1.5 * stats.median;
                if !(endgame && reactive) {
                    continue;
                }
                actions.push(if w.job(job).deadline_driven || task.progress() > 0.5 {
                    Action::Speculate(t)
                } else {
                    Action::Rerun(t)
                });
            }
        }
        let (features, dispatch) = self.predictor.take_spans();
        let decide = interval_start.elapsed().saturating_sub(features + dispatch);
        self.spans = Some(PredictSpans { features, dispatch, decide });
        actions
    }

    fn take_predict_spans(&mut self) -> Option<PredictSpans> {
        self.spans.take()
    }

    fn on_task_complete(&mut self, w: &World, task: TaskId) {
        let job = w.task(task).job;
        // The engine flips the job to Done only after this callback; the
        // registry's active-task counter is already 0 for the last
        // completion, so use it — otherwise the GRU hidden state for
        // every finished job leaks for the whole run.
        if !w.job(job).is_active() || w.job_active_count(job) == 0 {
            self.predictor.forget(job);
            self.predictions.remove(&job);
        }
    }

    fn predicted_stragglers(&mut self, job: JobId) -> Option<f64> {
        self.final_predictions.remove(&job)
    }
}
