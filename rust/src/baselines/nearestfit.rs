//! NearestFit [6]: statistical progress profiling via a `a + b·x^c` fit of
//! response time against task input size, fully online.
//!
//! Vanilla NearestFit only *detects* (it is a progress indicator); per the
//! paper's §4.6 we add speculation on the detected tasks for a fair
//! comparison.  Detection: a running task whose elapsed time exceeds
//! `factor ×` the fitted prediction for its size is a straggler.  Note the
//! profile is global — NearestFit does not differentiate hosts by
//! computational capacity, the weakness the paper calls out.

use crate::mitigation::Action;
use crate::ml::PowerFit;
use crate::predictor::FeatureExtractor;
use crate::sim::engine::Manager;
use crate::sim::types::*;
use crate::sim::world::World;

pub struct NearestFitManager {
    /// (input size, response) observations from completed tasks.
    xs: Vec<f64>,
    ys: Vec<f64>,
    fit: Option<PowerFit>,
    pub factor: f64,
    /// Refit cadence (observations between refits).
    refit_every: usize,
    since_refit: usize,
}

impl NearestFitManager {
    pub fn new() -> Self {
        Self { xs: Vec::new(), ys: Vec::new(), fit: None, factor: 1.6, refit_every: 25, since_refit: 0 }
    }

    /// Predicted response time for a task size (None before first fit).
    pub fn predict(&self, size: f64) -> Option<f64> {
        self.fit.as_ref().map(|f| f.predict(size))
    }
}

impl Default for NearestFitManager {
    fn default() -> Self {
        Self::new()
    }
}

impl Manager for NearestFitManager {
    fn name(&self) -> &'static str {
        "NearestFit"
    }

    fn on_task_complete(&mut self, w: &World, task: TaskId) {
        let t = w.task(task);
        self.xs.push(t.length_mi);
        self.ys.push(w.now - t.submit_t);
        if self.xs.len() > 2000 {
            self.xs.drain(..1000);
            self.ys.drain(..1000);
        }
        self.since_refit += 1;
        if self.since_refit >= self.refit_every && self.xs.len() >= 8 {
            self.fit = PowerFit::fit(&self.xs, &self.ys).or(self.fit.take());
            self.since_refit = 0;
        }
    }

    fn on_interval(&mut self, w: &World, _fx: &FeatureExtractor) -> Vec<Action> {
        let Some(fit) = &self.fit else { return Vec::new() };
        let mut actions = Vec::new();
        for &jid in w.active_jobs().iter() {
            for &t in &w.job(jid).tasks {
                let task = w.task(t);
                if task.is_running() && task.speculative_of.is_none() && !task.mitigated {
                    let expected = fit.predict(task.length_mi).max(1.0);
                    if w.now - task.submit_t > self.factor * expected {
                        actions.push(Action::Speculate(t));
                    }
                }
            }
        }
        actions
    }
}
