//! Golden parity: the O(active)-indexed `World` registry must reproduce
//! the seed engine's full-scan semantics **bit for bit**.
//!
//! `SimConfig::reference_scans` keeps the pre-refactor O(total) query
//! paths alive inside `World` (same arithmetic, seed iteration order).
//! Every technique is run twice from the same seed — indexed vs reference
//! — and the resulting `RunMetrics` are compared for exact equality, so
//! the paper's figures are provably unaffected by the refactor.
//!
//! Model-free techniques run hermetically; START / IGRU-SD join in when
//! the AOT artifacts are available.

use start_sim::baselines::{
    DollyManager, GrassManager, LateManager, NearestFitManager, RppsManager, SgcManager,
    WranglerManager,
};
use start_sim::config::{SchedulerKind, SimConfig, Technique};
use start_sim::coordinator::Models;
use start_sim::runtime::Manifest;
use start_sim::scheduler;
use start_sim::sim::engine::{Manager, NullManager, Simulation};
use start_sim::sim::RunMetrics;
use start_sim::util::rng::Pcg;

/// Managers constructible without AOT models.
fn model_free_manager(t: Technique) -> Box<dyn Manager> {
    match t {
        Technique::Wrangler => Box::new(WranglerManager::new()),
        Technique::Grass => Box::new(GrassManager::new()),
        Technique::Dolly => Box::new(DollyManager::new()),
        Technique::Sgc => Box::new(SgcManager::new()),
        Technique::NearestFit => Box::new(NearestFitManager::new()),
        Technique::Late => Box::new(LateManager::new()),
        Technique::Rpps => Box::new(RppsManager::new()),
        _ => Box::new(NullManager),
    }
}

fn parity_cfg(technique: Technique, reference: bool) -> SimConfig {
    let mut cfg = SimConfig::test_defaults();
    cfg.n_intervals = 10;
    cfg.n_workloads = 80;
    cfg.fault_rate = 1.0; // exercise resets, downtime, clone kills
    cfg.technique = technique;
    cfg.reference_scans = reference;
    cfg
}

fn run_with_cfg(cfg: SimConfig, technique: Technique) -> RunMetrics {
    let manifest =
        Manifest::load(start_sim::find_artifact_dir()).unwrap_or_else(|_| Manifest::test_default());
    let sched = scheduler::build(cfg.scheduler, Pcg::new(cfg.seed, 0x5C8E));
    let mut sim =
        Simulation::new(cfg.clone(), &manifest, sched, model_free_manager(technique));
    for _ in 0..cfg.n_intervals {
        sim.step_interval(true);
    }
    let mut extra = 0;
    let limit = cfg.drain_limit();
    while sim.world.has_active_jobs() && extra < limit {
        sim.step_interval(false);
        extra += 1;
    }
    sim.world.assert_consistent();
    sim.metrics
}

fn run_model_free(technique: Technique, reference: bool) -> RunMetrics {
    run_with_cfg(parity_cfg(technique, reference), technique)
}

/// Exact (bitwise-value) equality of every deterministic metric field.
/// `manager_overhead_s` is wall clock and deliberately excluded.
fn assert_metrics_identical(a: &RunMetrics, b: &RunMetrics, label: &str) {
    assert_eq!(a.tasks_done, b.tasks_done, "{label}: tasks_done");
    assert_eq!(a.jobs_done, b.jobs_done, "{label}: jobs_done");
    assert_eq!(a.speculations, b.speculations, "{label}: speculations");
    assert_eq!(a.reruns, b.reruns, "{label}: reruns");
    assert_eq!(a.exec_times, b.exec_times, "{label}: exec_times");
    assert_eq!(a.restart_times, b.restart_times, "{label}: restart_times");
    assert_eq!(a.completion_times, b.completion_times, "{label}: completion_times");
    assert_eq!(a.mitigation_delays, b.mitigation_delays, "{label}: mitigation_delays");
    assert_eq!(a.straggler_pred, b.straggler_pred, "{label}: straggler_pred");
    assert_eq!(a.sla_violated_weight, b.sla_violated_weight, "{label}: sla_violated_weight");
    assert_eq!(a.sla_total_weight, b.sla_total_weight, "{label}: sla_total_weight");
    assert_eq!(a.confusion.tp, b.confusion.tp, "{label}: confusion.tp");
    assert_eq!(a.confusion.fp, b.confusion.fp, "{label}: confusion.fp");
    assert_eq!(a.confusion.fn_, b.confusion.fn_, "{label}: confusion.fn");
    assert_eq!(a.confusion.tn, b.confusion.tn, "{label}: confusion.tn");
    assert_eq!(a.intervals.len(), b.intervals.len(), "{label}: interval count");
    for (i, (x, y)) in a.intervals.iter().zip(&b.intervals).enumerate() {
        assert_eq!(x.t, y.t, "{label}: interval {i} t");
        assert_eq!(x.energy_kwh, y.energy_kwh, "{label}: interval {i} energy");
        assert_eq!(x.cpu_util, y.cpu_util, "{label}: interval {i} cpu");
        assert_eq!(x.ram_util, y.ram_util, "{label}: interval {i} ram");
        assert_eq!(x.disk_util, y.disk_util, "{label}: interval {i} disk");
        assert_eq!(x.net_util, y.net_util, "{label}: interval {i} net");
        assert_eq!(x.contention, y.contention, "{label}: interval {i} contention");
        assert_eq!(x.active_tasks, y.active_tasks, "{label}: interval {i} active_tasks");
        assert_eq!(x.hosts_down, y.hosts_down, "{label}: interval {i} hosts_down");
    }
}

#[test]
fn indexed_world_is_bit_identical_for_model_free_techniques() {
    for technique in [
        Technique::None,
        Technique::Late,
        Technique::Grass,
        Technique::Dolly,
        Technique::Sgc,
        Technique::Wrangler,
        Technique::NearestFit,
        Technique::Rpps,
    ] {
        let indexed = run_model_free(technique, false);
        let reference = run_model_free(technique, true);
        assert!(indexed.tasks_done > 0, "{}: empty run", technique.name());
        assert_metrics_identical(&indexed, &reference, technique.name());
    }
}

#[test]
fn indexed_world_is_bit_identical_across_seeds_and_faults() {
    for (seed, fault_rate) in [(7u64, 0.0), (11, 2.5), (23, 0.6)] {
        let run = |reference: bool| {
            let mut cfg = parity_cfg(Technique::Grass, reference);
            cfg.seed = seed;
            cfg.fault_rate = fault_rate;
            let manifest = Manifest::load(start_sim::find_artifact_dir())
                .unwrap_or_else(|_| Manifest::test_default());
            let sched = scheduler::build(cfg.scheduler, Pcg::new(cfg.seed, 0x5C8E));
            Simulation::new(cfg, &manifest, sched, model_free_manager(Technique::Grass)).run()
        };
        let label = format!("grass seed={seed} faults={fault_rate}");
        assert_metrics_identical(&run(false), &run(true), &label);
    }
}

/// Placement-heavy cell: high arrival pressure and frequent faults so the
/// run is dominated by `Scheduler::pick`, availability churn and the
/// per-host aggregates — the paths this PR made O(1)/O(available).  Every
/// scheduler kind must replay bit-identically against the reference
/// scans for every model-free technique.
#[test]
fn indexed_world_is_bit_identical_for_all_scheduler_kinds() {
    for kind in [
        SchedulerKind::Random,
        SchedulerKind::RoundRobin,
        SchedulerKind::MinMin,
        SchedulerKind::A3c,
    ] {
        for technique in [
            Technique::None,
            Technique::Late,
            Technique::Grass,
            Technique::Dolly,
            Technique::Sgc,
            Technique::Wrangler,
            Technique::NearestFit,
            Technique::Rpps,
        ] {
            let run = |reference: bool| {
                let mut cfg = parity_cfg(technique, reference);
                cfg.scheduler = kind;
                cfg.n_intervals = 6;
                cfg.n_workloads = 160; // ~2.3 tasks/VM of arrival pressure
                cfg.fault_rate = 1.5; // heavy availability churn
                run_with_cfg(cfg, technique)
            };
            let indexed = run(false);
            let reference = run(true);
            let label = format!("{:?}/{}", kind, technique.name());
            assert!(indexed.tasks_done > 0, "{label}: empty run");
            assert_metrics_identical(&indexed, &reference, &label);
        }
    }
}

#[test]
fn indexed_world_is_bit_identical_for_model_techniques() {
    // START / IGRU-SD need the AOT models; covered when artifacts exist.
    let Ok(models) = Models::load_default() else {
        eprintln!("skipping model-technique parity: AOT artifacts/PJRT unavailable");
        return;
    };
    for technique in [Technique::Start, Technique::IgruSd] {
        let run = |reference: bool| {
            let cfg = parity_cfg(technique, reference);
            start_sim::coordinator::run_one(&cfg, &models).expect(technique.name())
        };
        assert_metrics_identical(&run(false), &run(true), technique.name());
    }
}
