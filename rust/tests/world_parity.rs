//! Golden parity: the O(active)-indexed `World` registry must reproduce
//! the seed engine's full-scan semantics **bit for bit**.
//!
//! `SimConfig::reference_scans` keeps the pre-refactor O(total) query
//! paths alive inside `World` (same arithmetic, seed iteration order).
//! Every technique is run twice from the same seed — indexed vs reference
//! — and the resulting `RunMetrics` are compared for exact equality, so
//! the paper's figures are provably unaffected by the refactor.
//!
//! Model-free techniques run hermetically; START / IGRU-SD join in when
//! the AOT artifacts are available.

use start_sim::config::{SchedulerKind, SimConfig, Technique};
use start_sim::coordinator::{model_free_manager, Models};
use start_sim::runtime::Manifest;
use start_sim::scheduler;
use start_sim::sim::engine::Simulation;
use start_sim::sim::RunMetrics;
use start_sim::util::rng::Pcg;

/// Manager constructible without AOT models (shared with the coordinator
/// and `trace_replay.rs`).
fn manager_for(t: Technique) -> Box<dyn start_sim::sim::engine::Manager> {
    model_free_manager(t).expect("model-free technique")
}

fn parity_cfg(technique: Technique, reference: bool) -> SimConfig {
    let mut cfg = SimConfig::test_defaults();
    cfg.n_intervals = 10;
    cfg.n_workloads = 80;
    cfg.fault_rate = 1.0; // exercise resets, downtime, clone kills
    cfg.technique = technique;
    cfg.reference_scans = reference;
    cfg
}

fn run_with_cfg(cfg: SimConfig, technique: Technique) -> RunMetrics {
    let manifest =
        Manifest::load(start_sim::find_artifact_dir()).unwrap_or_else(|_| Manifest::test_default());
    let sched = scheduler::build(cfg.scheduler, Pcg::new(cfg.seed, 0x5C8E));
    let mut sim = Simulation::new(cfg.clone(), &manifest, sched, manager_for(technique));
    for _ in 0..cfg.n_intervals {
        sim.step_interval(true);
    }
    let mut extra = 0;
    let limit = cfg.drain_limit();
    while sim.world.has_active_jobs() && extra < limit {
        sim.step_interval(false);
        extra += 1;
    }
    sim.world.assert_consistent();
    sim.metrics
}

fn run_model_free(technique: Technique, reference: bool) -> RunMetrics {
    run_with_cfg(parity_cfg(technique, reference), technique)
}

/// Exact (bitwise-value) equality of every deterministic metric field —
/// the shared contract in `RunMetrics::assert_deterministic_eq` (wall
/// clock / phase profile deliberately excluded; `trace_replay.rs` holds
/// the event stream to the same standard).
fn assert_metrics_identical(a: &RunMetrics, b: &RunMetrics, label: &str) {
    a.assert_deterministic_eq(b, label);
}

#[test]
fn indexed_world_is_bit_identical_for_model_free_techniques() {
    for technique in [
        Technique::None,
        Technique::Late,
        Technique::Grass,
        Technique::Dolly,
        Technique::Sgc,
        Technique::Wrangler,
        Technique::NearestFit,
        Technique::Rpps,
    ] {
        let indexed = run_model_free(technique, false);
        let reference = run_model_free(technique, true);
        assert!(indexed.tasks_done > 0, "{}: empty run", technique.name());
        assert_metrics_identical(&indexed, &reference, technique.name());
    }
}

#[test]
fn indexed_world_is_bit_identical_across_seeds_and_faults() {
    for (seed, fault_rate) in [(7u64, 0.0), (11, 2.5), (23, 0.6)] {
        let run = |reference: bool| {
            let mut cfg = parity_cfg(Technique::Grass, reference);
            cfg.seed = seed;
            cfg.fault_rate = fault_rate;
            let manifest = Manifest::load(start_sim::find_artifact_dir())
                .unwrap_or_else(|_| Manifest::test_default());
            let sched = scheduler::build(cfg.scheduler, Pcg::new(cfg.seed, 0x5C8E));
            Simulation::new(cfg, &manifest, sched, manager_for(Technique::Grass)).run()
        };
        let label = format!("grass seed={seed} faults={fault_rate}");
        assert_metrics_identical(&run(false), &run(true), &label);
    }
}

/// Placement-heavy cell: high arrival pressure and frequent faults so the
/// run is dominated by `Scheduler::pick`, availability churn and the
/// per-host aggregates — the paths this PR made O(1)/O(available).  Every
/// scheduler kind must replay bit-identically against the reference
/// scans for every model-free technique.
#[test]
fn indexed_world_is_bit_identical_for_all_scheduler_kinds() {
    for kind in [
        SchedulerKind::Random,
        SchedulerKind::RoundRobin,
        SchedulerKind::MinMin,
        SchedulerKind::A3c,
    ] {
        for technique in [
            Technique::None,
            Technique::Late,
            Technique::Grass,
            Technique::Dolly,
            Technique::Sgc,
            Technique::Wrangler,
            Technique::NearestFit,
            Technique::Rpps,
        ] {
            let run = |reference: bool| {
                let mut cfg = parity_cfg(technique, reference);
                cfg.scheduler = kind;
                cfg.n_intervals = 6;
                cfg.n_workloads = 160; // ~2.3 tasks/VM of arrival pressure
                cfg.fault_rate = 1.5; // heavy availability churn
                run_with_cfg(cfg, technique)
            };
            let indexed = run(false);
            let reference = run(true);
            let label = format!("{:?}/{}", kind, technique.name());
            assert!(indexed.tasks_done > 0, "{label}: empty run");
            assert_metrics_identical(&indexed, &reference, &label);
        }
    }
}

#[test]
fn indexed_world_is_bit_identical_for_model_techniques() {
    // START / IGRU-SD need the AOT models; covered when artifacts exist.
    let Ok(models) = Models::load_default() else {
        eprintln!("skipping model-technique parity: AOT artifacts/PJRT unavailable");
        return;
    };
    for technique in [Technique::Start, Technique::IgruSd] {
        let run = |reference: bool| {
            let cfg = parity_cfg(technique, reference);
            start_sim::coordinator::run_one(&cfg, &models).expect(technique.name())
        };
        assert_metrics_identical(&run(false), &run(true), technique.name());
    }
}
