//! Integration: PJRT runtime numerics parity with the Python golden vectors.
//! This pins the entire AOT bridge (jax -> HLO text -> xla crate -> PJRT).
//!
//! Skips (instead of failing) when the artifact directory or the PJRT
//! backend is unavailable, so the hermetic simulator test suite stays
//! green on machines without `make artifacts` / the `pjrt` feature.

use start_sim::runtime::{LstmState, Manifest, PjrtRuntime, StartModel};
use start_sim::util::json;
use std::path::PathBuf;

fn runtime() -> Option<(PathBuf, Manifest, PjrtRuntime)> {
    let dir = start_sim::find_artifact_dir();
    let manifest = match Manifest::load(&dir) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipping golden test: no artifact manifest ({e:#})");
            return None;
        }
    };
    let rt = match PjrtRuntime::new(&dir) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("skipping golden test: PJRT unavailable ({e:#})");
            return None;
        }
    };
    Some((dir, manifest, rt))
}

fn load_golden(dir: &std::path::Path) -> json::Json {
    let text = std::fs::read_to_string(dir.join("golden.json")).expect("golden.json");
    json::parse(&text).expect("golden parses")
}

fn close(a: f64, b: f64, tol: f64) -> bool {
    (a - b).abs() <= tol * (1.0 + b.abs())
}

#[test]
fn start_step_matches_python() {
    let Some((dir, manifest, rt)) = runtime() else { return };
    let model = StartModel::load(&rt, &manifest).expect("model");
    let golden = load_golden(&dir);
    let g = golden.get("start_step").expect("start_step golden");
    let inputs = g.get("inputs").unwrap().as_arr().unwrap();
    let outputs = g.get("outputs").unwrap().as_arr().unwrap();
    let m_h = inputs[0].as_f32_vec().unwrap();
    let m_t = inputs[1].as_f32_vec().unwrap();
    let state = LstmState {
        h1: inputs[2].as_f32_vec().unwrap(),
        c1: inputs[3].as_f32_vec().unwrap(),
        h2: inputs[4].as_f32_vec().unwrap(),
        c2: inputs[5].as_f32_vec().unwrap(),
    };
    let (alpha, beta, next) = model.step(&m_h, &m_t, &state).expect("step");
    let want_alpha = outputs[0].as_f32_vec().unwrap()[0] as f64;
    let want_beta = outputs[1].as_f32_vec().unwrap()[0] as f64;
    assert!(close(alpha, want_alpha, 1e-4), "alpha {alpha} want {want_alpha}");
    assert!(close(beta, want_beta, 1e-4), "beta {beta} want {want_beta}");
    let want_h1 = outputs[2].as_f32_vec().unwrap();
    for (got, want) in next.h1.iter().zip(&want_h1) {
        assert!(close(*got as f64, *want as f64, 1e-4), "h1 {got} want {want}");
    }
}

#[test]
fn start_rollout_matches_python() {
    let Some((dir, manifest, rt)) = runtime() else { return };
    let model = StartModel::load(&rt, &manifest).expect("model");
    let golden = load_golden(&dir);
    let g = golden.get("start_rollout").expect("rollout golden");
    let inputs = g.get("inputs").unwrap().as_arr().unwrap();
    let outputs = g.get("outputs").unwrap().as_arr().unwrap();
    let (alpha, beta) = model
        .rollout(&inputs[0].as_f32_vec().unwrap(), &inputs[1].as_f32_vec().unwrap())
        .expect("rollout");
    let want_alpha = outputs[0].as_f32_vec().unwrap()[0] as f64;
    let want_beta = outputs[1].as_f32_vec().unwrap()[0] as f64;
    assert!(close(alpha, want_alpha, 1e-4), "alpha {alpha} want {want_alpha}");
    assert!(close(beta, want_beta, 1e-4), "beta {beta} want {want_beta}");
}

#[test]
fn igru_matches_python() {
    let Some((dir, manifest, rt)) = runtime() else { return };
    let model = start_sim::runtime::IgruModel::load(&rt, &manifest).expect("igru");
    let golden = load_golden(&dir);
    let g = golden.get("igru_step").expect("igru golden");
    let inputs = g.get("inputs").unwrap().as_arr().unwrap();
    let outputs = g.get("outputs").unwrap().as_arr().unwrap();
    let (pred, hidden) = model
        .step(&inputs[0].as_f32_vec().unwrap(), &inputs[1].as_f32_vec().unwrap())
        .expect("step");
    let want_pred = outputs[0].as_f32_vec().unwrap();
    for (got, want) in pred.iter().zip(&want_pred) {
        assert!(close(*got as f64, *want as f64, 1e-4), "pred {got} want {want}");
    }
    assert_eq!(hidden.len(), manifest.igru_hidden);
}
