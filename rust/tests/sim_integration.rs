//! Integration tests over the simulator substrate (no PJRT needed):
//! determinism, conservation, fault semantics, property checks.

use start_sim::config::SimConfig;
use start_sim::predictor::FeatureExtractor;
use start_sim::runtime::Manifest;
use start_sim::scheduler;
use start_sim::sim::engine::{NullManager, Simulation};
use start_sim::sim::types::TaskState;
use start_sim::util::ptest;
use start_sim::util::rng::Pcg;

fn manifest() -> Manifest {
    // Use the real manifest when artifacts exist; else a canned one so the
    // simulator suite runs hermetically without `make artifacts`.
    Manifest::load(start_sim::find_artifact_dir()).unwrap_or_else(|_| Manifest::test_default())
}

fn run(cfg: SimConfig) -> start_sim::sim::RunMetrics {
    let m = manifest();
    let sched = scheduler::build(cfg.scheduler, Pcg::seeded(cfg.seed ^ 0xAB));
    Simulation::new(cfg, &m, sched, Box::new(NullManager)).run()
}

#[test]
fn paper_scale_fleet_constructs() {
    let cfg = SimConfig::paper_defaults();
    let w = start_sim::sim::World::new(&cfg);
    assert_eq!(w.vms.len(), 400);
    assert_eq!(w.hosts.len(), 47);
}

#[test]
fn property_conservation_across_fault_rates() {
    ptest::check("task-conservation", 6, |rng| {
        let mut cfg = SimConfig::test_defaults();
        cfg.seed = rng.next_u64();
        cfg.fault_rate = rng.range(0.0, 3.0);
        cfg.n_intervals = 10;
        cfg.n_workloads = 50;
        let m = run(cfg);
        if m.tasks_done == 0 {
            return Err("no tasks completed".into());
        }
        Ok(())
    });
}

#[test]
fn property_sla_rate_bounded() {
    ptest::check("sla-bounded", 5, |rng| {
        let mut cfg = SimConfig::test_defaults();
        cfg.seed = rng.next_u64();
        cfg.n_intervals = 10;
        cfg.n_workloads = 40;
        let m = run(cfg);
        let r = m.sla_violation_rate();
        if !(0.0..=1.0).contains(&r) {
            return Err(format!("sla rate {r} out of [0,1]"));
        }
        Ok(())
    });
}

#[test]
fn feature_extractor_consistent_with_generative_goldens() {
    // The golden.json generative pins are covered in runtime_golden.rs via
    // manifest constants; here we check live matrices stay in range.
    let cfg = SimConfig::test_defaults();
    let m = manifest();
    let mut w = start_sim::sim::World::new(&cfg);
    let mut fx = FeatureExtractor::new(&m);
    fx.snapshot(&mut w);
    assert!(fx.m_h().iter().all(|&x| x.is_finite() && x >= 0.0));
}

#[test]
fn held_tasks_eventually_complete() {
    // Even under a heavy fault storm (one fault per interval over a
    // 9-host fleet), nothing is left non-completed.  Rates much beyond
    // this re-break tasks faster than they can finish on this tiny fleet.
    let mut cfg = SimConfig::test_defaults();
    cfg.fault_rate = 1.2;
    cfg.n_intervals = 10;
    cfg.n_workloads = 40;
    let man = manifest();
    let sched = scheduler::build(cfg.scheduler, Pcg::seeded(5));
    let mut sim = Simulation::new(cfg.clone(), &man, sched, Box::new(NullManager));
    for _ in 0..cfg.n_intervals {
        sim.step_interval(true);
    }
    let mut extra = 0;
    // Triple headroom over the engine's drain bound: the fault storm
    // asserts completion and historically needed up to 1000 intervals.
    let limit = 3 * sim.cfg.drain_limit();
    while sim.world.has_active_jobs() && extra < limit {
        sim.step_interval(false);
        extra += 1;
    }
    for t in sim.world.debug_tasks().iter().filter(|t| t.speculative_of.is_none()) {
        assert!(
            matches!(t.state, TaskState::Completed { .. }),
            "task {} stuck in {:?} after fault storm",
            t.id,
            t.state
        );
    }
    sim.world.assert_consistent();
}
