//! Golden replay: the JSONL event trace is a *complete* record of a run —
//! `trace::replay(events)` must re-derive `RunMetrics` from the stream
//! alone, **bit-identical** to the live run (DESIGN.md §10).
//!
//! Every scheduler kind × model-free technique is run on the
//! placement-heavy cell (high arrival pressure + heavy fault churn, the
//! same cell as `world_parity.rs`), in both the indexed and
//! `reference_scans` modes, with a memory sink installed; the replayed
//! metrics are compared field-by-field with the same exactness contract
//! as the world-parity suite.  Wall-clock (the phase profiler) is
//! measurement, not simulation state, and is excluded by
//! `RunMetrics::diff_deterministic`.
#![cfg(feature = "sim-trace")]

use start_sim::config::{SchedulerKind, SimConfig, Technique};
use start_sim::coordinator::model_free_manager;
use start_sim::runtime::Manifest;
use start_sim::scheduler;
use start_sim::sim::engine::Simulation;
use start_sim::sim::trace::{self, Event, Phase, TraceSink};
use start_sim::sim::RunMetrics;
use start_sim::util::rng::Pcg;

const SCHEDULERS: [SchedulerKind; 4] = [
    SchedulerKind::Random,
    SchedulerKind::RoundRobin,
    SchedulerKind::MinMin,
    SchedulerKind::A3c,
];

const MODEL_FREE: [Technique; 8] = [
    Technique::None,
    Technique::Late,
    Technique::Grass,
    Technique::Dolly,
    Technique::Sgc,
    Technique::Wrangler,
    Technique::NearestFit,
    Technique::Rpps,
];

/// Placement-heavy cell: ~2.3 tasks/VM of arrival pressure with heavy
/// availability churn, so the stream exercises every event type
/// (placements, kills, resets, holds, clones, faults, vetoes).
fn traced_cfg(kind: SchedulerKind, technique: Technique, reference: bool) -> SimConfig {
    let mut cfg = SimConfig::test_defaults();
    cfg.scheduler = kind;
    cfg.technique = technique;
    cfg.reference_scans = reference;
    cfg.n_intervals = 6;
    cfg.n_workloads = 160;
    cfg.fault_rate = 1.5;
    cfg
}

/// Full run (intervals + drain) with a memory sink installed.
fn run_traced_cell(cfg: &SimConfig) -> (RunMetrics, Vec<Event>) {
    let manifest =
        Manifest::load(start_sim::find_artifact_dir()).unwrap_or_else(|_| Manifest::test_default());
    let sched = scheduler::build(cfg.scheduler, Pcg::new(cfg.seed, 0x5C8E));
    let manager = model_free_manager(cfg.technique).expect("model-free technique");
    let mut sim = Simulation::new(cfg.clone(), &manifest, sched, manager);
    sim.set_trace(TraceSink::mem());
    let (metrics, sink) = sim.run_traced();
    (metrics, sink.into_events())
}

#[test]
fn replay_is_bit_identical_for_every_scheduler_and_technique() {
    for kind in SCHEDULERS {
        for technique in MODEL_FREE {
            for reference in [false, true] {
                let cfg = traced_cfg(kind, technique, reference);
                let (live, events) = run_traced_cell(&cfg);
                let label = format!(
                    "{:?}/{}/{}",
                    kind,
                    technique.name(),
                    if reference { "reference" } else { "indexed" }
                );
                assert!(live.tasks_done > 0, "{label}: empty run");
                assert!(!events.is_empty(), "{label}: empty trace");
                let replayed = trace::replay(&events);
                live.assert_deterministic_eq(&replayed, &label);
            }
        }
    }
}

#[test]
fn replay_survives_a_jsonl_file_round_trip() {
    let cfg = traced_cfg(SchedulerKind::MinMin, Technique::Grass, false);
    let path = std::env::temp_dir().join("start_sim_trace_replay_roundtrip.jsonl");

    // Stream the run through the real file sink (BufWriter + finish).
    let manifest =
        Manifest::load(start_sim::find_artifact_dir()).unwrap_or_else(|_| Manifest::test_default());
    let sched = scheduler::build(cfg.scheduler, Pcg::new(cfg.seed, 0x5C8E));
    let manager = model_free_manager(cfg.technique).expect("model-free technique");
    let mut sim = Simulation::new(cfg.clone(), &manifest, sched, manager);
    sim.set_trace(TraceSink::file(&path).expect("file sink"));
    let (live, mut sink) = sim.run_traced();
    let n = sink.finish().expect("flush");
    assert!(n > 0, "no events streamed");

    // The file alone reconstructs the run, bit for bit.
    let events = trace::load_jsonl(&path).expect("load jsonl");
    assert_eq!(events.len(), n, "event count survives the file round trip");
    live.assert_deterministic_eq(&trace::replay(&events), "jsonl file round trip");

    // And a second serialization of the parsed stream is byte-stable.
    let mut buf = Vec::new();
    trace::write_jsonl(&events, &mut buf).expect("re-serialize");
    let reparsed = trace::read_jsonl(std::str::from_utf8(&buf).unwrap()).expect("re-parse");
    assert_eq!(events, reparsed, "JSONL round trip is lossless");
    let _ = std::fs::remove_file(&path);
}

/// Fig. 10 regression: `manager_overhead_s` now has one shared
/// definition — the profiler's predict+mitigate counters.  The engine
/// times the two phases with contiguous `Instant`s, so their sum spans
/// exactly the old lump measurement around the manager block; this pins
/// the delegation chain (metrics method == profile method == raw
/// counters) bitwise on a seeded run, plus basic sanity of the counters.
#[test]
fn fig10_overhead_is_the_profiler_predict_plus_mitigate() {
    let cfg = traced_cfg(SchedulerKind::RoundRobin, Technique::Grass, false);
    let (m, _) = run_traced_cell(&cfg);

    let from_counters =
        (m.profile.nanos(Phase::Predict) + m.profile.nanos(Phase::Mitigate)) as f64 * 1e-9;
    assert_eq!(m.manager_overhead_s().to_bits(), m.profile.manager_overhead_s().to_bits());
    assert_eq!(m.manager_overhead_s().to_bits(), from_counters.to_bits());

    assert!(m.manager_overhead_s().is_finite());
    assert!(m.manager_overhead_s() >= 0.0);
    assert!(m.manager_overhead_s() <= m.profile.total_seconds());
    // Both phases are timed once per step (intervals + drain).
    let steps = m.intervals.len() as u64;
    assert_eq!(m.profile.calls(Phase::Predict), steps);
    assert_eq!(m.profile.calls(Phase::Mitigate), steps);
    assert!(m.profile.total_seconds() > 0.0, "profiler recorded nothing");
}
