//! Chaos tests for the fault-tolerant coordinator (DESIGN.md §12).
//!
//! Everything here is hermetic: cells run through the `manager_override`
//! fault-injection hook (or model-free techniques), so no AOT artifacts
//! or PJRT backend are needed.  The batch machinery under test is the
//! real one — worker pool, retry/backoff, panic isolation, deadlines,
//! journal, resume.

use start_sim::config::{SimConfig, Technique};
use start_sim::coordinator::{
    journal, run_many_cells, run_many_opts, Cell, CellOutcome, ManagerFactory, RunOpts,
};
use start_sim::mitigation::Action;
use start_sim::predictor::FeatureExtractor;
use start_sim::sim::engine::{Manager, NullManager};
use start_sim::sim::World;
use start_sim::util::ptest;
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// A cell small enough that a whole chaos batch runs in well under a
/// second.
fn tiny_cfg(seed: u64) -> SimConfig {
    let mut cfg = SimConfig::test_defaults();
    cfg.pm_counts = vec![2, 1, 1];
    cfg.n_intervals = 6;
    cfg.n_workloads = 60;
    cfg.technique = Technique::None;
    cfg.seed = seed;
    cfg
}

fn cells_for(seeds: &[u64]) -> Vec<Cell> {
    seeds.iter().map(|&s| Cell { label: format!("chaos|None|{s}"), cfg: tiny_cfg(s) }).collect()
}

/// Base options for chaos runs: instant backoff (the schedule itself is
/// covered by a coordinator unit test), partial-results mode.
fn chaos_opts(retries: u32, factory: ManagerFactory) -> RunOpts {
    RunOpts {
        keep_going: true,
        retries,
        backoff_base: Duration::ZERO,
        backoff_cap: Duration::ZERO,
        manager_override: Some(factory),
        ..RunOpts::default()
    }
}

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("start_sim_resilience_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// A manager that panics on its Nth `on_interval` call.
struct PanickingManager {
    calls: usize,
    panic_at: usize,
}

impl Manager for PanickingManager {
    fn name(&self) -> &'static str {
        "Panic"
    }
    fn on_interval(&mut self, _w: &World, _fx: &FeatureExtractor) -> Vec<Action> {
        self.calls += 1;
        if self.calls >= self.panic_at {
            panic!("injected chaos panic at interval {}", self.calls);
        }
        Vec::new()
    }
}

/// A manager that sleeps every interval — a "hung" cell for the
/// deadline/watchdog path.
struct SlowManager {
    per_interval: Duration,
}

impl Manager for SlowManager {
    fn name(&self) -> &'static str {
        "Slow"
    }
    fn on_interval(&mut self, _w: &World, _fx: &FeatureExtractor) -> Vec<Action> {
        std::thread::sleep(self.per_interval);
        Vec::new()
    }
}

fn assert_ok(o: &CellOutcome) {
    assert!(o.result.is_ok(), "{}: {:#}", o.label, o.result.as_ref().err().unwrap());
}

fn err_text(o: &CellOutcome) -> String {
    format!("{:#}", o.result.as_ref().err().expect("expected a failed cell"))
}

// ---------------------------------------------------------- panic isolation

#[test]
fn injected_panic_is_a_per_cell_error_with_no_sibling_loss() {
    let seeds = [1u64, 2, 3, 4, 5, 6];
    let factory: ManagerFactory = Arc::new(|cfg: &SimConfig| {
        if cfg.seed == 3 {
            Ok(Box::new(PanickingManager { calls: 0, panic_at: 2 }) as Box<dyn Manager>)
        } else {
            Ok(Box::new(NullManager) as Box<dyn Manager>)
        }
    });
    let outcomes =
        run_many_cells(cells_for(&seeds), 3, PathBuf::from("unused"), chaos_opts(0, factory))
            .unwrap();
    assert_eq!(outcomes.len(), seeds.len(), "sibling cells were lost");
    for (o, &seed) in outcomes.iter().zip(&seeds) {
        assert_eq!(o.label, format!("chaos|None|{seed}"), "submission order broken");
        if seed == 3 {
            let msg = err_text(o);
            assert!(msg.contains("injected chaos panic"), "unexpected error: {msg}");
            assert_eq!(o.attempts, 1);
        } else {
            assert_ok(o);
            assert!(o.result.as_ref().unwrap().tasks_done > 0, "{}: empty run", o.label);
        }
    }
}

// ------------------------------------------------------------ retry/backoff

/// Factory that fails (Err or panic) the first `fail_n` builds for each
/// seed, then succeeds — a transient fault.
fn flaky_factory(
    fail_n: HashMap<u64, u32>,
    panic_instead: bool,
    built: Arc<AtomicUsize>,
) -> ManagerFactory {
    let counts: Arc<Mutex<HashMap<u64, u32>>> = Arc::new(Mutex::new(HashMap::new()));
    Arc::new(move |cfg: &SimConfig| {
        built.fetch_add(1, Ordering::SeqCst);
        let mut counts = counts.lock().unwrap();
        let seen = counts.entry(cfg.seed).or_insert(0);
        *seen += 1;
        if *seen <= fail_n.get(&cfg.seed).copied().unwrap_or(0) {
            if panic_instead {
                panic!("injected transient panic (build {seen})");
            }
            anyhow::bail!("injected transient failure (build {seen})");
        }
        Ok(Box::new(NullManager) as Box<dyn Manager>)
    })
}

#[test]
fn bounded_retry_recovers_transient_failures() {
    let built = Arc::new(AtomicUsize::new(0));
    let factory = flaky_factory(HashMap::from([(2u64, 2u32)]), false, Arc::clone(&built));
    let outcomes =
        run_many_cells(cells_for(&[1, 2, 3]), 2, PathBuf::from("unused"), chaos_opts(2, factory))
            .unwrap();
    for o in &outcomes {
        assert_ok(o);
    }
    assert_eq!(outcomes[1].attempts, 3, "two transient failures then success");
    assert_eq!(outcomes[0].attempts, 1);
    assert_eq!(outcomes[2].attempts, 1);
    assert_eq!(built.load(Ordering::SeqCst), 5, "1 + 3 + 1 manager builds");
}

#[test]
fn retry_exhaustion_surfaces_as_per_cell_error() {
    let built = Arc::new(AtomicUsize::new(0));
    // Seed 2 fails more times than the retry budget allows.
    let factory = flaky_factory(HashMap::from([(2u64, 99u32)]), false, built);
    let outcomes =
        run_many_cells(cells_for(&[1, 2, 3]), 2, PathBuf::from("unused"), chaos_opts(1, factory))
            .unwrap();
    assert_ok(&outcomes[0]);
    assert_ok(&outcomes[2]);
    let msg = err_text(&outcomes[1]);
    assert!(msg.contains("failed after 2 attempt"), "unexpected error: {msg}");
    assert!(msg.contains("injected transient failure"), "root cause lost: {msg}");
    assert_eq!(outcomes[1].attempts, 2);
}

#[test]
fn strict_mode_fails_fast_and_cancels_queued_cells() {
    // One worker; cell 1 always fails, the healthy factories sleep long
    // enough that the leader's cancellation drain reliably wins the race
    // for the tail of the queue.
    let make_factory = || -> ManagerFactory {
        Arc::new(|cfg: &SimConfig| {
            if cfg.seed == 1 {
                anyhow::bail!("injected transient failure");
            }
            std::thread::sleep(Duration::from_millis(100));
            Ok(Box::new(NullManager) as Box<dyn Manager>)
        })
    };
    let mut opts = chaos_opts(0, make_factory());
    opts.keep_going = false;
    let outcomes =
        run_many_cells(cells_for(&[1, 2, 3]), 1, PathBuf::from("unused"), opts).unwrap();
    assert!(err_text(&outcomes[0]).contains("injected transient failure"));
    // Cell 2 may have been dequeued by the worker before the leader saw
    // the failure; either way it must be accounted for.  Cell 3 sits
    // behind the 100 ms factory sleep, so the drain always reaches it.
    match &outcomes[1].result {
        Ok(_) => {}
        Err(_) => assert!(err_text(&outcomes[1]).contains("cancelled")),
    }
    assert!(err_text(&outcomes[2]).contains("cancelled"), "tail cell not cancelled");
    assert_eq!(outcomes[2].attempts, 0);

    let mut opts = chaos_opts(0, make_factory());
    opts.keep_going = false;
    let err = run_many_opts(cells_for(&[1, 2, 3]), 1, PathBuf::from("unused"), opts)
        .expect_err("strict mode must fail the batch");
    assert!(format!("{err:#}").contains("injected transient failure"));
}

// ----------------------------------------------------------------- deadline

#[test]
fn deadline_times_out_hung_cell_without_stalling_siblings() {
    let factory: ManagerFactory = Arc::new(|cfg: &SimConfig| {
        if cfg.seed == 1 {
            Ok(Box::new(SlowManager { per_interval: Duration::from_millis(60) })
                as Box<dyn Manager>)
        } else {
            Ok(Box::new(NullManager) as Box<dyn Manager>)
        }
    });
    let mut opts = chaos_opts(0, factory);
    opts.cell_timeout = Some(Duration::from_millis(90));
    let outcomes =
        run_many_cells(cells_for(&[1, 2]), 2, PathBuf::from("unused"), opts).unwrap();
    let msg = err_text(&outcomes[0]);
    assert!(msg.contains("deadline"), "unexpected error: {msg}");
    assert_ok(&outcomes[1]);
}

// ----------------------------------------------------------- journal/resume

/// The kill-mid-batch acceptance test, simulated deterministically: an
/// "interrupted" run completes only half its cells (the rest fail via
/// injected faults, so they are never journaled) and tears the journal's
/// final line mid-write; the resumed run must execute exactly the missing
/// cells and be bit-identical — per `RunMetrics::diff_deterministic` — to
/// an uninterrupted reference batch.
#[test]
fn kill_mid_batch_then_resume_is_bit_identical() {
    let dir = tmp_dir("resume");
    let journal_path = dir.join("results.jsonl");
    let seeds = [1u64, 2, 3, 4, 5, 6];
    let healthy: ManagerFactory = Arc::new(|_: &SimConfig| Ok(Box::new(NullManager) as Box<dyn Manager>));

    // Reference: uninterrupted batch, no journal.
    let reference =
        run_many_cells(cells_for(&seeds), 2, PathBuf::from("unused"), chaos_opts(0, healthy))
            .unwrap();

    // "Interrupted" run: cells with seed > 3 fail, so the journal ends up
    // holding exactly the first three cells.
    let crashy: ManagerFactory = Arc::new(|cfg: &SimConfig| {
        if cfg.seed > 3 {
            anyhow::bail!("simulated crash before completion");
        }
        Ok(Box::new(NullManager) as Box<dyn Manager>)
    });
    let mut opts = chaos_opts(0, crashy);
    opts.journal = Some(journal_path.clone());
    let outcomes =
        run_many_cells(cells_for(&seeds), 2, PathBuf::from("unused"), opts).unwrap();
    assert_eq!(outcomes.iter().filter(|o| o.result.is_ok()).count(), 3);
    // The crash also tears the last journal line mid-write.
    {
        use std::io::Write as _;
        let mut f = std::fs::OpenOptions::new().append(true).open(&journal_path).unwrap();
        write!(f, "{{\"cell\":\"torn\",\"cfg\":\"00").unwrap();
    }

    // Resume with a healthy factory that counts how many cells re-run.
    let built = Arc::new(AtomicUsize::new(0));
    let counting: ManagerFactory = {
        let built = Arc::clone(&built);
        Arc::new(move |_: &SimConfig| {
            built.fetch_add(1, Ordering::SeqCst);
            Ok(Box::new(NullManager) as Box<dyn Manager>)
        })
    };
    let mut opts = chaos_opts(0, counting);
    opts.journal = Some(journal_path.clone());
    opts.resume = true;
    let resumed = run_many_cells(cells_for(&seeds), 2, PathBuf::from("unused"), opts).unwrap();

    assert_eq!(built.load(Ordering::SeqCst), 3, "resume must only run the missing cells");
    for (o, r) in resumed.iter().zip(&reference) {
        assert_eq!(o.label, r.label);
        let (got, want) = (o.result.as_ref().unwrap(), r.result.as_ref().unwrap());
        got.assert_deterministic_eq(want, &o.label);
        let seed: u64 = o.label.rsplit('|').next().unwrap().parse().unwrap();
        assert_eq!(o.from_journal, seed <= 3, "{}", o.label);
        assert_eq!(o.attempts, if seed <= 3 { 0 } else { 1 }, "{}", o.label);
    }
    // After the resumed run the journal covers the whole batch: a second
    // resume re-runs nothing.
    let map = journal::load_map(&journal_path).unwrap();
    assert_eq!(map.len(), seeds.len());
    let _ = std::fs::remove_dir_all(&dir);
}

/// Kill → compact → resume bit-identity: after an interrupted run leaves
/// the journal with a superseded duplicate record (a retry re-append)
/// and a torn final line, `journal::compact` must preserve exactly the
/// resume view, and a resumed batch over the compacted journal (with
/// post-batch `--compact` hygiene on) must be bit-identical to an
/// uninterrupted reference batch.
#[test]
fn kill_compact_resume_is_bit_identical() {
    let dir = tmp_dir("compact_resume");
    let journal_path = dir.join("results.jsonl");
    let seeds = [1u64, 2, 3, 4, 5, 6];
    let healthy: ManagerFactory =
        Arc::new(|_: &SimConfig| Ok(Box::new(NullManager) as Box<dyn Manager>));

    // Reference: uninterrupted batch, no journal.
    let reference =
        run_many_cells(cells_for(&seeds), 2, PathBuf::from("unused"), chaos_opts(0, healthy))
            .unwrap();

    // "Interrupted" run: only seeds 1–3 complete and get journaled.
    let crashy: ManagerFactory = Arc::new(|cfg: &SimConfig| {
        if cfg.seed > 3 {
            anyhow::bail!("simulated crash before completion");
        }
        Ok(Box::new(NullManager) as Box<dyn Manager>)
    });
    let mut opts = chaos_opts(0, crashy);
    opts.journal = Some(journal_path.clone());
    run_many_cells(cells_for(&seeds), 2, PathBuf::from("unused"), opts).unwrap();

    // Crash aftermath: one record duplicated byte-for-byte (a cell
    // re-appended after a crash-window retry) plus a torn final line.
    {
        use std::io::Write as _;
        let text = std::fs::read_to_string(&journal_path).unwrap();
        let first = text.lines().next().unwrap().to_string();
        let mut f = std::fs::OpenOptions::new().append(true).open(&journal_path).unwrap();
        writeln!(f, "{first}").unwrap();
        write!(f, "{{\"cell\":\"torn\",\"cfg\":\"00").unwrap();
    }
    let before = journal::load_map(&journal_path).unwrap();
    assert_eq!(before.len(), 3);

    // Compaction drops the superseded duplicate and the torn line but
    // leaves the resume view untouched.
    let (kept, dropped) = journal::compact(&journal_path).unwrap();
    assert_eq!((kept, dropped), (3, 2));
    let after = journal::load_map(&journal_path).unwrap();
    assert_eq!(after.len(), before.len());
    for (key, m) in &before {
        assert!(m.diff_deterministic(&after[key]).is_none(), "{key:?}");
    }

    // Resume over the compacted journal, with post-batch compaction on.
    let built = Arc::new(AtomicUsize::new(0));
    let counting: ManagerFactory = {
        let built = Arc::clone(&built);
        Arc::new(move |_: &SimConfig| {
            built.fetch_add(1, Ordering::SeqCst);
            Ok(Box::new(NullManager) as Box<dyn Manager>)
        })
    };
    let mut opts = chaos_opts(0, counting);
    opts.journal = Some(journal_path.clone());
    opts.resume = true;
    opts.compact = true;
    let resumed = run_many_cells(cells_for(&seeds), 2, PathBuf::from("unused"), opts).unwrap();
    assert_eq!(built.load(Ordering::SeqCst), 3, "resume must only run the missing cells");
    for (o, r) in resumed.iter().zip(&reference) {
        assert_eq!(o.label, r.label);
        let (got, want) = (o.result.as_ref().unwrap(), r.result.as_ref().unwrap());
        got.assert_deterministic_eq(want, &o.label);
    }
    // Post-run hygiene: one line per cell, still resume-complete.
    let text = std::fs::read_to_string(&journal_path).unwrap();
    assert_eq!(text.lines().count(), seeds.len());
    assert_eq!(journal::load_map(&journal_path).unwrap().len(), seeds.len());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn changed_config_invalidates_journaled_cell() {
    let dir = tmp_dir("digest");
    let journal_path = dir.join("results.jsonl");
    let healthy: ManagerFactory = Arc::new(|_: &SimConfig| Ok(Box::new(NullManager) as Box<dyn Manager>));
    let mut opts = chaos_opts(0, Arc::clone(&healthy));
    opts.journal = Some(journal_path.clone());
    run_many_cells(cells_for(&[1]), 1, PathBuf::from("unused"), opts).unwrap();

    // Same label, different config: the digest must force a re-run.
    let mut cells = cells_for(&[1]);
    cells[0].cfg.n_workloads += 1;
    let built = Arc::new(AtomicUsize::new(0));
    let counting: ManagerFactory = {
        let built = Arc::clone(&built);
        Arc::new(move |_: &SimConfig| {
            built.fetch_add(1, Ordering::SeqCst);
            Ok(Box::new(NullManager) as Box<dyn Manager>)
        })
    };
    let mut opts = chaos_opts(0, counting);
    opts.journal = Some(journal_path.clone());
    opts.resume = true;
    let outcomes = run_many_cells(cells, 1, PathBuf::from("unused"), opts).unwrap();
    assert!(!outcomes[0].from_journal, "stale journal record must not be reused");
    assert_eq!(built.load(Ordering::SeqCst), 1);
    let _ = std::fs::remove_dir_all(&dir);
}

// --------------------------------------------------- trace-file collisions

#[test]
fn colliding_labels_keep_distinct_trace_files() {
    let dir = tmp_dir("traces");
    let healthy: ManagerFactory = Arc::new(|_: &SimConfig| Ok(Box::new(NullManager) as Box<dyn Manager>));
    // Both labels sanitize to `col_X_1`.
    let cells = vec![
        Cell { label: "col|X|1".into(), cfg: tiny_cfg(1) },
        Cell { label: "col_X_1".into(), cfg: tiny_cfg(2) },
    ];
    let mut opts = chaos_opts(0, healthy);
    opts.trace_dir = Some(dir.clone());
    let outcomes = run_many_cells(cells, 1, PathBuf::from("unused"), opts).unwrap();
    for o in &outcomes {
        assert_ok(o);
    }
    let first = std::fs::read_to_string(dir.join("col_X_1.jsonl")).unwrap();
    let second = std::fs::read_to_string(dir.join("col_X_1__2.jsonl")).unwrap();
    assert!(!first.is_empty() && !second.is_empty());
    assert_ne!(first, second, "the colliding cell overwrote its sibling's trace");
    let _ = std::fs::remove_dir_all(&dir);
}

// ------------------------------------------------------------- ptest chaos

/// Randomized chaos: arbitrary mixes of healthy, always-panicking and
/// transiently-failing cells over random worker counts.  Invariants: no
/// sibling loss (every submitted cell reports an outcome, in order),
/// healthy and transient cells succeed, hopeless cells fail cleanly.
#[test]
fn ptest_chaos_mix_never_loses_cells() {
    ptest::check("coordinator-chaos", 6, |rng| {
        let n_cells = 3 + rng.below(4); // 3..=6
        let threads = 1 + rng.below(3); // 1..=3
        let retries = 1u32;
        // Per-seed chaos plan: 0 = healthy, 1 = fail once (recoverable),
        // 2 = always panic (hopeless).
        let plan: Vec<u8> =
            (0..n_cells).map(|_| [0u8, 0, 1, 2][rng.below(4)]).collect();
        let seeds: Vec<u64> = (0..n_cells as u64).map(|i| i + 1).collect();
        let plan_by_seed: HashMap<u64, u8> =
            seeds.iter().copied().zip(plan.iter().copied()).collect();
        let fails: HashMap<u64, u32> = plan_by_seed
            .iter()
            .filter(|(_, &p)| p == 1)
            .map(|(&s, _)| (s, 1u32))
            .collect();
        let counts: Arc<Mutex<HashMap<u64, u32>>> = Arc::new(Mutex::new(HashMap::new()));
        let factory: ManagerFactory = {
            let plan = plan_by_seed.clone();
            Arc::new(move |cfg: &SimConfig| {
                match plan.get(&cfg.seed).copied().unwrap_or(0) {
                    2 => Ok(Box::new(PanickingManager { calls: 0, panic_at: 1 }) as Box<dyn Manager>),
                    1 => {
                        let mut counts = counts.lock().unwrap();
                        let seen = counts.entry(cfg.seed).or_insert(0);
                        *seen += 1;
                        if *seen <= *fails.get(&cfg.seed).unwrap_or(&0) {
                            anyhow::bail!("transient");
                        }
                        Ok(Box::new(NullManager) as Box<dyn Manager>)
                    }
                    _ => Ok(Box::new(NullManager) as Box<dyn Manager>),
                }
            })
        };
        let outcomes = run_many_cells(
            cells_for(&seeds),
            threads,
            PathBuf::from("unused"),
            chaos_opts(retries, factory),
        )
        .map_err(|e| format!("batch-level failure: {e:#}"))?;
        if outcomes.len() != n_cells {
            return Err(format!("lost cells: {} of {n_cells}", outcomes.len()));
        }
        for (i, o) in outcomes.iter().enumerate() {
            let seed = seeds[i];
            if o.label != format!("chaos|None|{seed}") {
                return Err(format!("order broken at {i}: {}", o.label));
            }
            let p = plan_by_seed[&seed];
            match (p, o.result.is_ok()) {
                (2, true) => return Err(format!("hopeless cell {seed} succeeded")),
                (2, false) => {
                    if !err_text(o).contains("injected chaos panic") {
                        return Err(format!("wrong error for {seed}: {}", err_text(o)));
                    }
                }
                (_, false) => {
                    return Err(format!("cell {seed} (plan {p}) failed: {}", err_text(o)))
                }
                _ => {}
            }
        }
        Ok(())
    });
}
