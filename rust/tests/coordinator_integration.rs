//! Integration: full coordinator runs with the real AOT models for every
//! technique, on a scaled-down cloud.
//!
//! These tests skip (instead of failing) when the AOT artifacts or the
//! PJRT backend are unavailable — the model-free simulator suite covers
//! everything that does not need a compiled network.

use start_sim::config::{SimConfig, Technique};
use start_sim::coordinator::{run_one, Models};

fn quick_cfg(technique: Technique) -> SimConfig {
    let mut cfg = SimConfig::test_defaults();
    cfg.n_intervals = 16;
    cfg.n_workloads = 120;
    cfg.technique = technique;
    cfg
}

fn load_models() -> Option<Models> {
    match Models::load_default() {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("skipping coordinator test: models unavailable ({e:#})");
            None
        }
    }
}

#[test]
fn all_techniques_run_to_completion() {
    let Some(models) = load_models() else { return };
    for technique in Technique::paper_set() {
        let cfg = quick_cfg(technique);
        let m = run_one(&cfg, &models).expect(technique.name());
        assert!(m.jobs_done > 0, "{}: no jobs done", technique.name());
        assert!(m.tasks_done > 50, "{}: only {} tasks", technique.name(), m.tasks_done);
        assert!(m.avg_execution_time() > 0.0, "{}", technique.name());
        assert!(m.total_energy_kwh() > 0.0, "{}", technique.name());
    }
}

#[test]
fn start_predictions_are_finite_and_positive() {
    let Some(models) = load_models() else { return };
    let cfg = quick_cfg(Technique::Start);
    let m = run_one(&cfg, &models).expect("run");
    assert!(!m.straggler_pred.is_empty());
    for &(pred, actual) in &m.straggler_pred {
        assert!(pred.is_finite() && pred >= 0.0, "prediction {pred}");
        assert!(actual >= 0.0);
    }
    // START actually mitigates something under faults.
    assert!(m.speculations + m.reruns > 0, "no mitigation actions fired");
}

#[test]
fn start_mitigation_beats_no_management() {
    let Some(models) = load_models() else { return };
    let mut sum_start = 0.0;
    let mut sum_none = 0.0;
    for seed in [11, 23, 37] {
        let mut cfg = quick_cfg(Technique::Start);
        cfg.seed = seed;
        cfg.fault_rate = 1.0;
        sum_start += run_one(&cfg, &models).expect("start").avg_execution_time();
        cfg.technique = Technique::None;
        sum_none += run_one(&cfg, &models).expect("none").avg_execution_time();
    }
    assert!(
        sum_start < sum_none,
        "START ({sum_start:.1}) should beat None ({sum_none:.1}) on exec time"
    );
}
