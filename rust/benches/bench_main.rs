//! Benchmark harness (`cargo bench`).  Criterion is unavailable offline,
//! so this is a self-contained harness with warmup, repetition, and
//! p50/p95 reporting — one benchmark group per paper table/figure plus
//! micro-benchmarks of the hot paths (DESIGN.md §4, §7).
//!
//! Figure benches run the *fast profile* so `cargo bench` completes in
//! minutes; `start-sim experiment <fig> --paper` regenerates the
//! paper-scale numbers.
//!
//! The `scale` group measures the O(active) world registry against the
//! seed engine's O(total) reference scans at 1×/10×/50× task counts and
//! writes machine-readable results to `BENCH_scale.json` (the perf
//! trajectory the CI workflow archives).

use start_sim::config::{SchedulerKind, SimConfig, Technique};
use start_sim::coordinator::{run_one, Models};
use start_sim::experiments::{figures, Profile};
use start_sim::pareto::Pareto;
use start_sim::predictor::{FeatureExtractor, StartPredictor};
use start_sim::runtime::{Manifest, StartModel};
use start_sim::sim::engine::{NullManager, Simulation};
use start_sim::sim::World;
use start_sim::util::rng::Pcg;
use start_sim::util::stats::Summary;
use std::time::Instant;

/// Time `f` with warmup; returns per-iteration seconds (sorted samples).
fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    let s = Summary::of(&samples);
    println!(
        "bench {name:42} iters {iters:4}  mean {:>10.3?}  p50 {:>10.3?}  p95 {:>10.3?}",
        secs(s.mean),
        secs(s.p50),
        secs(s.p95)
    );
    s
}

fn secs(s: f64) -> std::time::Duration {
    std::time::Duration::from_secs_f64(s.max(0.0))
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).filter(|a| !a.starts_with('-')).collect();
    let filter = args.first().cloned().unwrap_or_default();
    let run = |name: &str| filter.is_empty() || name.contains(&filter);
    println!("start-sim bench harness (filter: {filter:?})\n");

    // ------------------------------------------ O(active) scaling cells
    if run("scale") {
        scale_benches();
    }
    // ---------------------------------------------------- micro benches
    if run("micro") {
        micro_benches();
    }
    // ------------------------------------------- per-figure regenerators
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let art = start_sim::find_artifact_dir();
    type FigFn = fn(Profile, usize, &std::path::PathBuf) -> anyhow::Result<start_sim::experiments::ExperimentResult>;
    let figs: Vec<(&str, FigFn)> = vec![
        ("fig2", figures::fig2 as FigFn),
        ("fig5", figures::fig5 as FigFn),
        ("fig6", figures::fig6 as FigFn),
        ("fig7", figures::fig7 as FigFn),
        ("fig8", figures::fig8 as FigFn),
        ("fig9", figures::fig9 as FigFn),
        ("fig10", figures::fig10 as FigFn),
        ("headline", figures::headline as FigFn),
    ];
    for (name, f) in figs {
        if !run(name) {
            continue;
        }
        let t0 = Instant::now();
        match f(Profile::Fast, threads, &art) {
            Ok(result) => {
                result.print();
                println!("bench {name}: regenerated in {:.1}s\n", t0.elapsed().as_secs_f64());
            }
            Err(e) => println!("bench {name}: FAILED: {e:#}"),
        }
    }
}

/// One full no-manager simulation; returns best-of-N wall seconds and
/// tasks done (best-of filters scheduler noise — a single cold run on a
/// busy machine can swing the small cells by several ×).
fn run_scale_cell(cfg: &SimConfig, manifest: &Manifest, reference: bool, reps: usize) -> (f64, usize) {
    let mut best = f64::INFINITY;
    let mut tasks = 0;
    for _ in 0..reps.max(1) {
        let mut c = cfg.clone();
        c.reference_scans = reference;
        let sched = start_sim::scheduler::build(c.scheduler, Pcg::seeded(7));
        let sim = Simulation::new(c, manifest, sched, Box::new(NullManager));
        let t0 = Instant::now();
        let m = sim.run();
        best = best.min(t0.elapsed().as_secs_f64());
        tasks = m.tasks_done;
    }
    (best, tasks)
}

/// The 1×/10×/50× scaling sweep: task budget and horizon grow together so
/// the per-interval *active* population stays flat while *total* tasks
/// grow — the regime where the indexed registry's O(active) queries beat
/// the seed engine's O(total) scans asymptotically.
fn scale_benches() {
    let manifest = Manifest::test_default();
    let mut cells = Vec::new();
    for &(scale, n_workloads, n_intervals) in
        &[(1usize, 200usize, 12usize), (10, 2_000, 120), (50, 10_000, 600)]
    {
        let mut cfg = SimConfig::test_defaults();
        cfg.scheduler = SchedulerKind::RoundRobin;
        cfg.n_workloads = n_workloads;
        cfg.n_intervals = n_intervals;
        // More reps where runs are fast (and noisiest); 2 at 50×.
        let reps = if scale >= 50 { 2 } else { 5 };
        let (indexed_s, tasks_done) = run_scale_cell(&cfg, &manifest, false, reps);
        let (reference_s, tasks_ref) = run_scale_cell(&cfg, &manifest, true, reps);
        assert_eq!(tasks_done, tasks_ref, "scale cell {scale}x: mode parity broken");
        let speedup = reference_s / indexed_s.max(1e-12);
        println!(
            "bench scale_{scale}x ({n_workloads} tasks / {n_intervals} iv)   indexed {:>9.3?}  reference {:>9.3?}  speedup {speedup:>6.1}x",
            secs(indexed_s),
            secs(reference_s),
        );
        cells.push(format!(
            "    {{\"scale\": {scale}, \"n_workloads\": {n_workloads}, \"n_intervals\": {n_intervals}, \
             \"tasks_done\": {tasks_done}, \"indexed_s\": {indexed_s:.6}, \
             \"reference_s\": {reference_s:.6}, \"speedup\": {speedup:.2}}}"
        ));
    }
    let json = format!(
        "{{\n  \"bench\": \"scale\",\n  \"unit\": \"seconds_wall\",\n  \"cells\": [\n{}\n  ]\n}}\n",
        cells.join(",\n")
    );
    match std::fs::write("BENCH_scale.json", &json) {
        Ok(()) => println!("bench scale: wrote BENCH_scale.json\n"),
        Err(e) => println!("bench scale: could not write BENCH_scale.json: {e}\n"),
    }
}

fn micro_benches() {
    let models = match Models::load_default() {
        Ok(m) => m,
        Err(e) => {
            println!("bench micro: skipped (AOT artifacts/PJRT unavailable: {e:#})\n");
            return;
        }
    };
    let manifest = &models.manifest;

    // Pareto MLE over a large sample (the per-job fitting path).
    let mut rng = Pcg::seeded(1);
    let samples: Vec<f64> = (0..10_000).map(|_| rng.pareto(2.0, 1.0)).collect();
    bench("pareto_mle_10k", 3, 50, || {
        let p = Pareto::mle(&samples).unwrap();
        std::hint::black_box(p);
    });

    // Feature extraction on the paper-scale fleet.
    let cfg = SimConfig::paper_defaults();
    let mut world = World::new(&cfg);
    let mut fx = FeatureExtractor::new(manifest);
    bench("feature_snapshot_47pm", 3, 100, || {
        fx.snapshot(&mut world);
    });

    // PJRT dispatch: single-step, fused rollout, batched rollout.
    let mh = vec![0.3f32; manifest.mh_len()];
    let mt = vec![0.2f32; manifest.mt_len()];
    let state = start_sim::runtime::LstmState::zeros(manifest.hidden);
    let model2 = StartModel::load(&models.runtime, manifest).unwrap();
    bench("pjrt_start_step", 5, 200, || {
        let out = model2.step(&mh, &mt, &state).unwrap();
        std::hint::black_box(out);
    });
    let mh_seq = vec![0.3f32; manifest.rollout_steps * manifest.mh_len()];
    let mt_seq = vec![0.2f32; manifest.rollout_steps * manifest.mt_len()];
    bench("pjrt_start_rollout_T5", 5, 200, || {
        let out = model2.rollout(&mh_seq, &mt_seq).unwrap();
        std::hint::black_box(out);
    });
    let mh_b = vec![0.3f32; manifest.rollout_steps * manifest.rollout_batch * manifest.mh_len()];
    let mt_b = vec![0.2f32; manifest.rollout_steps * manifest.rollout_batch * manifest.mt_len()];
    bench("pjrt_start_rollout_T5_B8", 5, 200, || {
        let out = model2.rollout_batch(&mh_b, &mt_b).unwrap();
        std::hint::black_box(out);
    });

    // Full predictor path (features + marshalling + dispatch) per job.
    let model3 = std::rc::Rc::new(StartModel::load(&models.runtime, manifest).unwrap());
    let mut predictor = StartPredictor::new(model3, 1.5);
    fx.snapshot(&mut world);
    world.add_job(start_sim::sim::Job {
        id: 0,
        tasks: vec![],
        submit_t: 0.0,
        deadline_driven: true,
        sla_deadline: 1e9,
        sla_weight: 1.0,
        state: start_sim::sim::JobState::Active,
        true_alpha: 2.0,
        true_beta: 1.0,
    });
    bench("predict_one_job_end_to_end", 3, 100, || {
        let p = predictor.predict(&world, &fx, 0).unwrap();
        std::hint::black_box(p);
    });

    // Simulator throughput on the fast profile, no manager.
    let mut fast = Profile::Fast.base_config();
    fast.n_intervals = 12;
    fast.n_workloads = 200;
    bench("sim_12_intervals_200_tasks", 1, 10, || {
        let sched = start_sim::scheduler::build(fast.scheduler, Pcg::seeded(7));
        let sim = Simulation::new(fast.clone(), &models.manifest, sched, Box::new(NullManager));
        std::hint::black_box(sim.run().tasks_done);
    });

    // One full START cell (the experiment unit of work).
    let mut cell = Profile::Fast.base_config();
    cell.n_intervals = 12;
    cell.n_workloads = 200;
    cell.technique = Technique::Start;
    bench("start_cell_12_intervals", 1, 5, || {
        let m = run_one(&cell, &models).unwrap();
        std::hint::black_box(m.tasks_done);
    });
    println!();
}
