//! Benchmark harness (`cargo bench`).  Criterion is unavailable offline,
//! so this is a self-contained harness with warmup, repetition, and
//! p50/p95 reporting — one benchmark group per paper table/figure plus
//! micro-benchmarks of the hot paths (DESIGN.md §4, §7).
//!
//! Figure benches run the *fast profile* so `cargo bench` completes in
//! minutes; `start-sim experiment <fig> --paper` regenerates the
//! paper-scale numbers.
//!
//! The `scale` group measures the O(active) world registry against the
//! seed engine's O(total) reference scans at 1×/10×/50× task counts; the
//! `placement` group measures the O(1) load accounting + availability
//! index (DESIGN.md §9) on a placement-bound profile (large fleet, heavy
//! arrivals, no faults); the `rates` group measures the dirty-host rate
//! recomputation + incremental finish-time heap (DESIGN.md §11) on a
//! completion-dense profile (short tasks, heavy arrivals, Dolly cloning).
//! All write machine-readable results to `BENCH_scale.json` /
//! `BENCH_placement.json` / `BENCH_rates.json` at the **repo root** (the
//! perf trajectory tracked per PR).
//!
//! Flags (after the optional name filter):
//!   --fast    run only the 1×/10× cells (the CI profile)
//!   --check   compare each measured indexed-vs-reference speedup against
//!             the `min_speedup` floor in the committed baseline file and
//!             exit non-zero on regression.  Speedup ratios are
//!             machine-independent, so the floors hold on any runner.

use start_sim::config::{SchedulerKind, SimConfig, Technique};
use start_sim::coordinator::{run_one, Models};
use start_sim::experiments::{figures, Profile};
use start_sim::pareto::Pareto;
use start_sim::predictor::{FeatureExtractor, StartPredictor};
use start_sim::runtime::{Manifest, StartModel};
use start_sim::sim::engine::{NullManager, Simulation};
use start_sim::sim::World;
use start_sim::util::json::{self, Json};
use start_sim::util::rng::Pcg;
use start_sim::util::stats::Summary;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::time::Instant;

/// Time `f` with warmup; returns per-iteration seconds (sorted samples).
fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    let s = Summary::of(&samples);
    println!(
        "bench {name:42} iters {iters:4}  mean {:>10.3?}  p50 {:>10.3?}  p95 {:>10.3?}",
        secs(s.mean),
        secs(s.p50),
        secs(s.p95)
    );
    s
}

fn secs(s: f64) -> std::time::Duration {
    std::time::Duration::from_secs_f64(s.max(0.0))
}

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let fast = raw.iter().any(|a| a == "--fast");
    let check = raw.iter().any(|a| a == "--check");
    let filter =
        raw.iter().find(|a| !a.starts_with('-')).cloned().unwrap_or_default();
    let run = |name: &str| filter.is_empty() || name.contains(&filter);
    println!("start-sim bench harness (filter: {filter:?}, fast: {fast}, check: {check})");
    // The `--check` floors must hold with the trace layer compiled in but
    // disabled (every sink below is TraceSink::off — the zero-cost path).
    println!(
        "sim-trace feature: {}; sinks disabled for all cells\n",
        if cfg!(feature = "sim-trace") { "compiled in" } else { "compiled out" }
    );

    let mut failures: Vec<String> = Vec::new();
    // ------------------------------------------ O(active) scaling cells
    if run("scale") {
        scale_benches(fast, check, &mut failures);
    }
    // ------------------------------- placement-bound cells (DESIGN.md §9)
    if run("placement") {
        placement_benches(fast, check, &mut failures);
    }
    // ------------------- completion-dense cells (DESIGN.md §11 dirty hosts)
    if run("rates") {
        rates_benches(fast, check, &mut failures);
    }
    // ---------------------------------------------------- micro benches
    if run("micro") {
        micro_benches();
    }
    // ------------------------------------------- per-figure regenerators
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let art = start_sim::find_artifact_dir();
    type FigFn = fn(
        Profile,
        usize,
        &std::path::PathBuf,
        &start_sim::experiments::ExpOpts,
    ) -> anyhow::Result<start_sim::experiments::ExperimentResult>;
    let fig_opts = start_sim::experiments::ExpOpts::default();
    let figs: Vec<(&str, FigFn)> = vec![
        ("fig2", figures::fig2 as FigFn),
        ("fig5", figures::fig5 as FigFn),
        ("fig6", figures::fig6 as FigFn),
        ("fig7", figures::fig7 as FigFn),
        ("fig8", figures::fig8 as FigFn),
        ("fig9", figures::fig9 as FigFn),
        ("fig10", figures::fig10 as FigFn),
        ("headline", figures::headline as FigFn),
    ];
    for (name, f) in figs {
        if !run(name) {
            continue;
        }
        let t0 = Instant::now();
        match f(Profile::Fast, threads, &art, &fig_opts) {
            Ok(result) => {
                result.print();
                println!("bench {name}: regenerated in {:.1}s\n", t0.elapsed().as_secs_f64());
            }
            Err(e) => println!("bench {name}: FAILED: {e:#}"),
        }
    }
    if !failures.is_empty() {
        eprintln!("\nbench --check FAILED ({} regression(s)):", failures.len());
        for f in &failures {
            eprintln!("  {f}");
        }
        std::process::exit(1);
    }
}

/// Repo root (one level above the crate): where the committed
/// `BENCH_*.json` trajectory files live.
fn repo_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(Path::to_path_buf)
        .unwrap_or_else(|| PathBuf::from("."))
}

/// Per-cell `min_speedup` floors from a committed baseline file.
fn load_floors(path: &Path) -> Option<BTreeMap<usize, f64>> {
    let text = std::fs::read_to_string(path).ok()?;
    let doc = json::parse(&text).ok()?;
    let mut floors = BTreeMap::new();
    for cell in doc.get("cells")?.as_arr()? {
        let scale = cell.get("scale")?.as_usize()?;
        if let Some(f) = cell.get("min_speedup").and_then(Json::as_f64) {
            floors.insert(scale, f);
        }
    }
    Some(floors)
}

/// One measured sweep cell (indexed and reference timings of one config).
struct CellResult {
    scale: usize,
    n_workloads: usize,
    n_intervals: usize,
    tasks_done: usize,
    indexed_s: f64,
    reference_s: f64,
}

/// Check measured speedups against the committed floors (read **before**
/// overwriting the baseline) and rewrite the trajectory file, carrying
/// each cell's floor forward.
fn finish_sweep(
    name: &str,
    file_name: &str,
    profile: &str,
    results: &[CellResult],
    default_floor: fn(usize) -> f64,
    check: bool,
    failures: &mut Vec<String>,
) {
    let path = repo_root().join(file_name);
    let floors = load_floors(&path);
    if check && floors.is_none() {
        failures.push(format!(
            "{name}: no readable committed baseline at {}",
            path.display()
        ));
    }
    let mut cells = Vec::new();
    for r in results {
        let floor = floors
            .as_ref()
            .and_then(|f| f.get(&r.scale).copied())
            .unwrap_or_else(|| default_floor(r.scale));
        let speedup = r.reference_s / r.indexed_s.max(1e-12);
        if check && speedup < floor {
            failures.push(format!(
                "{name} {}x: indexed-vs-reference speedup {speedup:.2}x regressed below \
                 the committed floor {floor:.2}x",
                r.scale
            ));
        }
        cells.push(format!(
            "    {{\"scale\": {}, \"n_workloads\": {}, \"n_intervals\": {}, \
             \"tasks_done\": {}, \"indexed_s\": {:.6}, \"reference_s\": {:.6}, \
             \"speedup\": {speedup:.2}, \"min_speedup\": {floor}}}",
            r.scale, r.n_workloads, r.n_intervals, r.tasks_done, r.indexed_s, r.reference_s
        ));
    }
    let json_text = format!(
        "{{\n  \"bench\": \"{name}\",\n  \"unit\": \"seconds_wall\",\n  \"profile\": \
         \"{profile}\",\n  \"cells\": [\n{}\n  ]\n}}\n",
        cells.join(",\n")
    );
    match std::fs::write(&path, &json_text) {
        Ok(()) => println!("bench {name}: wrote {}\n", path.display()),
        Err(e) => println!("bench {name}: could not write {}: {e}\n", path.display()),
    }
}

/// Committed floors for the `scale` sweep (mirrors BENCH_scale.json).
fn scale_floor(scale: usize) -> f64 {
    match scale {
        0..=1 => 0.8,
        2..=10 => 2.0,
        _ => 5.0,
    }
}

/// Committed floors for the `placement` sweep (mirrors
/// BENCH_placement.json; the 50× floor is the acceptance criterion).
fn placement_floor(scale: usize) -> f64 {
    match scale {
        0..=1 => 0.8,
        2..=10 => 2.0,
        _ => 3.0,
    }
}

/// Committed floors for the `rates` sweep (mirrors BENCH_rates.json;
/// the 50× floor is the PR's acceptance criterion).
fn rates_floor(scale: usize) -> f64 {
    match scale {
        0..=1 => 0.8,
        2..=10 => 2.0,
        _ => 3.0,
    }
}

/// One full no-manager simulation; returns best-of-N wall seconds and
/// tasks done (best-of filters scheduler noise — a single cold run on a
/// busy machine can swing the small cells by several ×).
fn run_scale_cell(cfg: &SimConfig, manifest: &Manifest, reference: bool, reps: usize) -> (f64, usize) {
    let mut best = f64::INFINITY;
    let mut tasks = 0;
    for _ in 0..reps.max(1) {
        let mut c = cfg.clone();
        c.reference_scans = reference;
        let sched = start_sim::scheduler::build(c.scheduler, Pcg::seeded(7));
        let sim = Simulation::new(c, manifest, sched, Box::new(NullManager));
        let t0 = Instant::now();
        let m = sim.run();
        best = best.min(t0.elapsed().as_secs_f64());
        tasks = m.tasks_done;
    }
    (best, tasks)
}

/// The 1×/10×/50× scaling sweep: task budget and horizon grow together so
/// the per-interval *active* population stays flat while *total* tasks
/// grow — the regime where the indexed registry's O(active) queries beat
/// the seed engine's O(total) scans asymptotically.
fn scale_benches(fast: bool, check: bool, failures: &mut Vec<String>) {
    let manifest = Manifest::test_default();
    let all = [(1usize, 200usize, 12usize), (10, 2_000, 120), (50, 10_000, 600)];
    let cells = if fast { &all[..2] } else { &all[..] };
    let mut results = Vec::new();
    for &(scale, n_workloads, n_intervals) in cells {
        let mut cfg = SimConfig::test_defaults();
        cfg.scheduler = SchedulerKind::RoundRobin;
        cfg.n_workloads = n_workloads;
        cfg.n_intervals = n_intervals;
        // More reps where runs are fast (and noisiest); 2 at 50×.
        let reps = if scale >= 50 { 2 } else { 5 };
        let (indexed_s, tasks_done) = run_scale_cell(&cfg, &manifest, false, reps);
        let (reference_s, tasks_ref) = run_scale_cell(&cfg, &manifest, true, reps);
        assert_eq!(tasks_done, tasks_ref, "scale cell {scale}x: mode parity broken");
        let speedup = reference_s / indexed_s.max(1e-12);
        println!(
            "bench scale_{scale}x ({n_workloads} tasks / {n_intervals} iv)   indexed {:>9.3?}  reference {:>9.3?}  speedup {speedup:>6.1}x",
            secs(indexed_s),
            secs(reference_s),
        );
        results.push(CellResult { scale, n_workloads, n_intervals, tasks_done, indexed_s, reference_s });
    }
    let profile = if fast { "fast" } else { "full" };
    finish_sweep("scale", "BENCH_scale.json", profile, &results, scale_floor, check, failures);
}

/// The placement-bound sweep: fleet size and arrival pressure grow
/// together while faults are disabled, so wall time is dominated by
/// `Scheduler::pick` over the candidate list and the per-candidate
/// host-load reads.  MinMin maximizes per-candidate work (it scores every
/// available VM), making this the sharpest probe of the O(1) load
/// accounting + availability index vs the reference rescans.
fn placement_benches(fast: bool, check: bool, failures: &mut Vec<String>) {
    let manifest = Manifest::test_default();
    // (scale, reps): fleet pm_counts and workload both scale; the 50×
    // cell is 3500 VMs placing 10k tasks over 10 intervals.
    let all = [(1usize, 5usize), (10, 3), (50, 2)];
    let cells = if fast { &all[..2] } else { &all[..] };
    let mut results = Vec::new();
    for &(scale, reps) in cells {
        let mut cfg = SimConfig::test_defaults();
        cfg.scheduler = SchedulerKind::MinMin;
        cfg.fault_rate = 0.0;
        for c in cfg.pm_counts.iter_mut() {
            *c *= scale;
        }
        let n_workloads = 200 * scale;
        let n_intervals = 10;
        cfg.n_workloads = n_workloads;
        cfg.n_intervals = n_intervals;
        let (indexed_s, tasks_done) = run_scale_cell(&cfg, &manifest, false, reps);
        let (reference_s, tasks_ref) = run_scale_cell(&cfg, &manifest, true, reps);
        assert_eq!(tasks_done, tasks_ref, "placement cell {scale}x: mode parity broken");
        let speedup = reference_s / indexed_s.max(1e-12);
        println!(
            "bench placement_{scale}x ({} vms / {n_workloads} tasks)   indexed {:>9.3?}  reference {:>9.3?}  speedup {speedup:>6.1}x",
            cfg.total_vms(),
            secs(indexed_s),
            secs(reference_s),
        );
        results.push(CellResult { scale, n_workloads, n_intervals, tasks_done, indexed_s, reference_s });
    }
    let profile = if fast { "fast" } else { "full" };
    finish_sweep(
        "placement",
        "BENCH_placement.json",
        profile,
        &results,
        placement_floor,
        check,
        failures,
    );
}

/// The completion-dense sweep: the regime where the dirty-host rate
/// recomputation (DESIGN.md §11) pays off.  Long intervals make most
/// tasks finish *within* an interval, so each `advance_to` processes a
/// dense stream of completions — and before §11 every one of them
/// triggered a full-fleet `recompute_rates`.  Dolly cloning multiplies
/// completion events further (every clone is an extra start + finish),
/// and a moderate fault rate sprinkles host invalidations in.  Scale
/// grows the *total* task population while the per-interval active set
/// stays flat, so the host-local recompute wins asymptotically.
fn rates_benches(fast: bool, check: bool, failures: &mut Vec<String>) {
    let manifest = Manifest::test_default();
    let all = [(1usize, 400usize, 8usize, 5usize), (10, 4_000, 80, 3), (50, 20_000, 400, 2)];
    let cells = if fast { &all[..2] } else { &all[..] };
    let mut results = Vec::new();
    for &(scale, n_workloads, n_intervals, reps) in cells {
        let mut cfg = SimConfig::test_defaults();
        cfg.scheduler = SchedulerKind::RoundRobin;
        cfg.technique = Technique::Dolly;
        cfg.n_workloads = n_workloads;
        cfg.n_intervals = n_intervals;
        // ~4× the default interval: short tasks relative to the interval,
        // i.e. a dense completion stream inside every advance_to.
        cfg.interval_s *= 4.0;
        cfg.job_lambda = 3.0;
        cfg.fault_rate = 0.25;
        let (indexed_s, tasks_done) = run_rates_cell(&cfg, &manifest, false, reps);
        let (reference_s, tasks_ref) = run_rates_cell(&cfg, &manifest, true, reps);
        assert_eq!(tasks_done, tasks_ref, "rates cell {scale}x: mode parity broken");
        let speedup = reference_s / indexed_s.max(1e-12);
        println!(
            "bench rates_{scale}x ({n_workloads} tasks / {n_intervals} iv, dolly)   indexed {:>9.3?}  reference {:>9.3?}  speedup {speedup:>6.1}x",
            secs(indexed_s),
            secs(reference_s),
        );
        results.push(CellResult { scale, n_workloads, n_intervals, tasks_done, indexed_s, reference_s });
    }
    let profile = if fast { "fast" } else { "full" };
    finish_sweep("rates", "BENCH_rates.json", profile, &results, rates_floor, check, failures);
}

/// Like [`run_scale_cell`] but with the Dolly cloning manager (a fresh
/// one per rep — managers carry per-run state).
fn run_rates_cell(cfg: &SimConfig, manifest: &Manifest, reference: bool, reps: usize) -> (f64, usize) {
    let mut best = f64::INFINITY;
    let mut tasks = 0;
    for _ in 0..reps.max(1) {
        let mut c = cfg.clone();
        c.reference_scans = reference;
        let sched = start_sim::scheduler::build(c.scheduler, Pcg::seeded(7));
        let manager = start_sim::coordinator::model_free_manager(c.technique)
            .expect("rates bench uses a model-free technique");
        let sim = Simulation::new(c, manifest, sched, manager);
        let t0 = Instant::now();
        let m = sim.run();
        best = best.min(t0.elapsed().as_secs_f64());
        tasks = m.tasks_done;
    }
    (best, tasks)
}

fn micro_benches() {
    let models = match Models::load_default() {
        Ok(m) => m,
        Err(e) => {
            println!("bench micro: skipped (AOT artifacts/PJRT unavailable: {e:#})\n");
            return;
        }
    };
    let manifest = &models.manifest;

    // Pareto MLE over a large sample (the per-job fitting path).
    let mut rng = Pcg::seeded(1);
    let samples: Vec<f64> = (0..10_000).map(|_| rng.pareto(2.0, 1.0)).collect();
    bench("pareto_mle_10k", 3, 50, || {
        let p = Pareto::mle(&samples).unwrap();
        std::hint::black_box(p);
    });

    // Feature extraction on the paper-scale fleet.
    let cfg = SimConfig::paper_defaults();
    let mut world = World::new(&cfg);
    let mut fx = FeatureExtractor::new(manifest);
    bench("feature_snapshot_47pm", 3, 100, || {
        fx.snapshot(&mut world);
    });

    // PJRT dispatch: single-step, fused rollout, batched rollout.
    let mh = vec![0.3f32; manifest.mh_len()];
    let mt = vec![0.2f32; manifest.mt_len()];
    let state = start_sim::runtime::LstmState::zeros(manifest.hidden);
    let model2 = StartModel::load(&models.runtime, manifest).unwrap();
    bench("pjrt_start_step", 5, 200, || {
        let out = model2.step(&mh, &mt, &state).unwrap();
        std::hint::black_box(out);
    });
    let mh_seq = vec![0.3f32; manifest.rollout_steps * manifest.mh_len()];
    let mt_seq = vec![0.2f32; manifest.rollout_steps * manifest.mt_len()];
    bench("pjrt_start_rollout_T5", 5, 200, || {
        let out = model2.rollout(&mh_seq, &mt_seq).unwrap();
        std::hint::black_box(out);
    });
    let mh_b = vec![0.3f32; manifest.rollout_steps * manifest.rollout_batch * manifest.mh_len()];
    let mt_b = vec![0.2f32; manifest.rollout_steps * manifest.rollout_batch * manifest.mt_len()];
    bench("pjrt_start_rollout_T5_B8", 5, 200, || {
        let out = model2.rollout_batch(&mh_b, &mt_b).unwrap();
        std::hint::black_box(out);
    });

    // Full predictor path (features + marshalling + dispatch) per job.
    let model3 = std::rc::Rc::new(StartModel::load(&models.runtime, manifest).unwrap());
    let mut predictor = StartPredictor::new(model3, 1.5);
    fx.snapshot(&mut world);
    world.add_job(start_sim::sim::Job {
        id: start_sim::sim::JobId::new(0),
        tasks: vec![],
        submit_t: 0.0,
        deadline_driven: true,
        sla_deadline: 1e9,
        sla_weight: 1.0,
        state: start_sim::sim::JobState::Active,
        true_alpha: 2.0,
        true_beta: 1.0,
    });
    bench("predict_one_job_end_to_end", 3, 100, || {
        let p = predictor.predict(&world, &fx, start_sim::sim::JobId::new(0)).unwrap();
        std::hint::black_box(p);
    });

    // Simulator throughput on the fast profile, no manager.
    let mut fast = Profile::Fast.base_config();
    fast.n_intervals = 12;
    fast.n_workloads = 200;
    bench("sim_12_intervals_200_tasks", 1, 10, || {
        let sched = start_sim::scheduler::build(fast.scheduler, Pcg::seeded(7));
        let sim = Simulation::new(fast.clone(), &models.manifest, sched, Box::new(NullManager));
        std::hint::black_box(sim.run().tasks_done);
    });

    // One full START cell (the experiment unit of work).
    let mut cell = Profile::Fast.base_config();
    cell.n_intervals = 12;
    cell.n_workloads = 200;
    cell.technique = Technique::Start;
    bench("start_cell_12_intervals", 1, 5, || {
        let m = run_one(&cell, &models).unwrap();
        std::hint::black_box(m.tasks_done);
    });
    println!();
}
