//! End-to-end validation driver (see DESIGN.md §4): the full
//! paper workload — 400 VMs over the Table 3 PM fleet, 5000 cloudlets,
//! 288 scheduling intervals (24 h), Weibull fault injection — for START
//! and all six baselines, 5 seeds each, reproducing the paper's §1
//! headline (−13 % exec time, −11 % contention, −16 % energy, −19 % SLA
//! violations vs the state of the art).
//!
//!     make artifacts && cargo run --release --example full_comparison
//!
//! Pass `--fast` for a scaled-down profile (~100 VMs).

use anyhow::Result;
use start_sim::config::Technique;
use start_sim::coordinator::{run_many, Cell};
use start_sim::experiments::{Profile, Table};
use start_sim::sim::RunMetrics;

fn main() -> Result<()> {
    let fast = std::env::args().any(|a| a == "--fast");
    let profile = if fast { Profile::Fast } else { Profile::Paper };
    let base = profile.base_config();
    let techniques = Technique::paper_set();
    let seeds = [42u64, 43, 44, 45, 46];
    println!(
        "full comparison: {} VMs / {} PMs, {} cloudlets, {} intervals × {} techniques × {} seeds",
        base.total_vms(),
        base.total_pms(),
        base.n_workloads,
        base.n_intervals,
        techniques.len(),
        seeds.len()
    );

    let mut cells = Vec::new();
    for &t in &techniques {
        for &seed in &seeds {
            let mut cfg = base.clone();
            cfg.technique = t;
            cfg.seed = seed;
            cells.push(Cell { label: format!("{}|{seed}", t.name()), cfg });
        }
    }
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let t0 = std::time::Instant::now();
    let results = run_many(cells, threads, start_sim::find_artifact_dir())?;
    println!("{} runs in {:.1}s\n", results.len(), t0.elapsed().as_secs_f64());

    let mean = |xs: &[f64]| xs.iter().sum::<f64>() / xs.len().max(1) as f64;
    let mut table = Table::new(
        "START vs baselines — paper workload (mean of 5 seeds)",
        &["technique", "exec (s)", "contention", "energy (kWh)", "SLA viol %", "MAPE %", "spec", "rerun"],
    );
    let mut start_row: Option<[f64; 4]> = None;
    let mut best = [f64::INFINITY; 4];
    for t in &techniques {
        let ms: Vec<&RunMetrics> = results
            .iter()
            .filter(|(l, _)| l.starts_with(&format!("{}|", t.name())))
            .map(|(_, m)| m)
            .collect();
        let exec = mean(&ms.iter().map(|m| m.avg_execution_time()).collect::<Vec<_>>());
        let cont = mean(&ms.iter().map(|m| m.avg_contention()).collect::<Vec<_>>());
        let energy = mean(&ms.iter().map(|m| m.total_energy_kwh()).collect::<Vec<_>>());
        let sla = mean(&ms.iter().map(|m| m.sla_violation_rate()).collect::<Vec<_>>());
        let mape = mean(&ms.iter().map(|m| m.straggler_mape()).collect::<Vec<_>>());
        let spec = mean(&ms.iter().map(|m| m.speculations as f64).collect::<Vec<_>>());
        let rerun = mean(&ms.iter().map(|m| m.reruns as f64).collect::<Vec<_>>());
        table.row(vec![
            t.name().to_string(),
            format!("{exec:.1}"),
            format!("{cont:.2}"),
            format!("{energy:.2}"),
            format!("{:.2}", 100.0 * sla),
            format!("{mape:.1}"),
            format!("{spec:.0}"),
            format!("{rerun:.0}"),
        ]);
        if t.name() == "START" {
            start_row = Some([exec, cont, energy, sla]);
        } else {
            best[0] = best[0].min(exec);
            best[1] = best[1].min(cont);
            best[2] = best[2].min(energy);
            best[3] = best[3].min(sla);
        }
    }
    println!("{}", table.render());

    if let Some(s) = start_row {
        println!("START vs best baseline per metric (paper targets in parentheses):");
        let names = [
            "execution time   (paper −13 %)",
            "contention       (paper −11 %)",
            "energy           (paper −16 %)",
            "SLA violations   (paper −19 %)",
        ];
        for i in 0..4 {
            let delta = 100.0 * (s[i] - best[i]) / best[i].max(1e-12);
            println!("  {:32}: {delta:+.1} %", names[i]);
        }
    }
    Ok(())
}
