//! Fault-storm scenario: sweep the Weibull fault-injection rate and watch
//! how START's proactive mitigation degrades vs the no-management floor —
//! the paper's motivation (§1: stragglers stem from faults + contention).
//!
//!     cargo run --release --example fault_storm

use anyhow::Result;
use start_sim::config::{SimConfig, Technique};
use start_sim::coordinator::{run_many, Cell};
use start_sim::experiments::Table;

fn main() -> Result<()> {
    let mut base = SimConfig::paper_defaults();
    base.pm_counts = vec![6, 4, 2]; // 100 VMs
    base.n_intervals = 48;
    base.n_workloads = 600;

    let mut cells = Vec::new();
    for &rate in &[0.0, 0.5, 1.0, 2.0, 4.0] {
        for t in [Technique::Start, Technique::None] {
            for seed in [42u64, 43, 44] {
                let mut cfg = base.clone();
                cfg.fault_rate = rate;
                cfg.technique = t;
                cfg.seed = seed;
                cells.push(Cell { label: format!("{rate}|{}|{seed}", t.name()), cfg });
            }
        }
    }
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let results = run_many(cells, threads, start_sim::find_artifact_dir())?;

    let mean_of = |rate: f64, tech: &str, f: &dyn Fn(&start_sim::sim::RunMetrics) -> f64| {
        let sel: Vec<f64> = results
            .iter()
            .filter(|(l, _)| l.starts_with(&format!("{rate}|{tech}|")))
            .map(|(_, m)| f(m))
            .collect();
        sel.iter().sum::<f64>() / sel.len().max(1) as f64
    };

    let mut table = Table::new(
        "Fault storm — exec time (s) and SLA violation (%) vs fault rate",
        &["faults/interval", "START exec", "None exec", "START SLA%", "None SLA%"],
    );
    for &rate in &[0.0, 0.5, 1.0, 2.0, 4.0] {
        table.row(vec![
            format!("{rate}"),
            format!("{:.0}", mean_of(rate, "START", &|m| m.avg_execution_time())),
            format!("{:.0}", mean_of(rate, "None", &|m| m.avg_execution_time())),
            format!("{:.1}", 100.0 * mean_of(rate, "START", &|m| m.sla_violation_rate())),
            format!("{:.1}", 100.0 * mean_of(rate, "None", &|m| m.sla_violation_rate())),
        ]);
    }
    println!("{}", table.render());
    println!("expected shape: both degrade with fault rate; START degrades slower.");
    Ok(())
}
