//! Quickstart: load the AOT Encoder-LSTM, run a small simulated cloud
//! with START managing stragglers, and report the QoS metrics.
//!
//!     make artifacts && cargo run --release --example quickstart

use anyhow::Result;
use start_sim::config::{SimConfig, Technique};
use start_sim::coordinator::{run_one, Models};

fn main() -> Result<()> {
    // 1. Load the AOT artifacts (HLO text → PJRT executables).
    let models = Models::load_default()?;
    println!(
        "loaded model: encoder ({}×{} hosts + {}×{} tasks) → 2×LSTM({}) → (α, β)",
        models.manifest.n_hosts,
        models.manifest.m_feats,
        models.manifest.q_tasks,
        models.manifest.p_feats,
        models.manifest.hidden,
    );
    println!("PJRT platform: {}", models.runtime.platform());

    // 2. A small cloud: ~100 VMs, 24 intervals, START managing stragglers.
    let mut cfg = SimConfig::paper_defaults();
    cfg.pm_counts = vec![6, 4, 2];
    cfg.n_intervals = 24;
    cfg.n_workloads = 300;
    cfg.technique = Technique::Start;

    println!(
        "\nsimulating {} VMs / {} PMs, {} cloudlets, {} intervals …",
        cfg.total_vms(),
        cfg.total_pms(),
        cfg.n_workloads,
        cfg.n_intervals
    );
    let m = run_one(&cfg, &models)?;

    // 3. Report.
    println!("\n— results (technique = START) —");
    println!("jobs completed     : {}", m.jobs_done);
    println!("tasks completed    : {}", m.tasks_done);
    println!("avg execution time : {:.1} s (Eq. 8)", m.avg_execution_time());
    println!("energy             : {:.2} kWh (Eq. 7)", m.total_energy_kwh());
    println!("SLA violation rate : {:.1} % (Eq. 13)", 100.0 * m.sla_violation_rate());
    println!("straggler MAPE     : {:.1} % (Eq. 14)", m.straggler_mape());
    println!("mitigations        : {} speculations, {} re-runs", m.speculations, m.reruns);
    println!("prediction overhead: {:.0} ms total", 1e3 * m.manager_overhead_s());
    Ok(())
}
