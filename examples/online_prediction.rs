//! Online prediction: drive the simulator interval by interval and stream
//! START's (α, β, E_S) predictions next to the eventual ground truth —
//! i.e. the Straggler Prediction module of Fig. 1/4 observed live.
//!
//!     cargo run --release --example online_prediction

use anyhow::Result;
use start_sim::config::{SimConfig, Technique};
use start_sim::coordinator::{build_manager, Models};
use start_sim::predictor::{FeatureExtractor, StartPredictor};
use start_sim::runtime::StartModel;
use start_sim::scheduler;
use start_sim::sim::engine::Simulation;
use start_sim::util::rng::Pcg;

fn main() -> Result<()> {
    let models = Models::load_default()?;
    let mut cfg = SimConfig::paper_defaults();
    cfg.pm_counts = vec![4, 3, 2];
    cfg.n_intervals = 30;
    cfg.n_workloads = 150;
    cfg.technique = Technique::Start;

    // Separate predictor instance for observation (the manager inside the
    // simulation owns its own).
    let model = std::rc::Rc::new(StartModel::load(&models.runtime, &models.manifest)?);
    let mut probe = StartPredictor::new(model, cfg.k_straggler);
    let mut fx = FeatureExtractor::new(&models.manifest);

    let sched = scheduler::build(cfg.scheduler, Pcg::new(cfg.seed, 0x5C8E));
    let manager = build_manager(cfg.technique, &models, &cfg)?;
    let mut sim = Simulation::new(cfg.clone(), &models.manifest, sched, manager);

    println!("interval |  active jobs | sample job:   alpha    beta     E_S   (q)");
    println!("---------+--------------+------------------------------------------");
    for interval in 0..cfg.n_intervals {
        sim.step_interval(true);
        fx.snapshot(&mut sim.world);
        let active = sim.world.active_jobs();
        if let Some(&job) = active.first() {
            let p = probe.predict(&sim.world, &fx, job)?;
            let q = sim.world.job(job).tasks.len();
            println!(
                "{interval:8} | {:12} | job {job:4}: {:7.3} {:7.3} {:7.2}  ({q})",
                active.len(),
                p.alpha,
                p.beta,
                p.expected
            );
        } else {
            println!("{interval:8} | {:12} |", active.len());
        }
    }

    // Drain and score.
    let metrics = {
        let mut extra = 0;
        let limit = cfg.drain_limit();
        while sim.world.has_active_jobs() && extra < limit {
            sim.step_interval(false);
            extra += 1;
        }
        sim.metrics
    };
    println!("\nfinal: {} jobs, straggler MAPE {:.1} % (Eq. 14), F1 {:.3}",
        metrics.jobs_done, metrics.straggler_mape(), metrics.confusion.f1());
    Ok(())
}
