"""Pure-jnp reference oracle for every Pallas kernel in this package.

The Pallas kernels in ``dense.py`` / ``lstm.py`` / ``gru.py`` are validated
against these functions by ``python/tests/test_kernel.py`` (hypothesis
sweeps over shapes and dtypes).  Keep these implementations boring: plain
``jnp`` ops, no pallas, no tricks — they ARE the correctness definition.
"""

import jax.numpy as jnp


def softplus(x):
    """Numerically-stable softplus: log(1 + exp(x))."""
    return jnp.logaddexp(x, 0.0)


def dense_ref(x, w, b, activation="softplus"):
    """y = act(x @ w + b).

    x: (B, IN), w: (IN, OUT), b: (OUT,) -> (B, OUT)
    """
    y = x @ w + b
    if activation == "softplus":
        return softplus(y)
    if activation == "relu":
        return jnp.maximum(y, 0.0)
    if activation == "tanh":
        return jnp.tanh(y)
    if activation == "none":
        return y
    raise ValueError(f"unknown activation {activation!r}")


def lstm_cell_ref(x, h, c, wx, wh, b):
    """Standard LSTM cell (gate order i, f, g, o).

    x: (B, IN), h/c: (B, H), wx: (IN, 4H), wh: (H, 4H), b: (4H,)
    Returns (h', c').
    """
    hidden = h.shape[-1]
    gates = x @ wx + h @ wh + b
    i, f, g, o = jnp.split(gates, 4, axis=-1)
    i = jnp.clip(jnp.nan_to_num(1.0 / (1.0 + jnp.exp(-i))), 0.0, 1.0)
    f = jnp.clip(jnp.nan_to_num(1.0 / (1.0 + jnp.exp(-f))), 0.0, 1.0)
    o = jnp.clip(jnp.nan_to_num(1.0 / (1.0 + jnp.exp(-o))), 0.0, 1.0)
    g = jnp.tanh(g)
    c_new = f * c + i * g
    h_new = o * jnp.tanh(c_new)
    assert h_new.shape[-1] == hidden
    return h_new, c_new


def gru_cell_ref(x, h, wx, wh, b):
    """Standard GRU cell (gate order r, z, n).

    x: (B, IN), h: (B, H), wx: (IN, 3H), wh: (H, 3H), b: (3H,)
    Returns h'.
    """
    hidden = h.shape[-1]
    gx = x @ wx + b
    gh = h @ wh
    rx, zx, nx = jnp.split(gx, 3, axis=-1)
    rh, zh, nh = jnp.split(gh, 3, axis=-1)
    r = 1.0 / (1.0 + jnp.exp(-(rx + rh)))
    z = 1.0 / (1.0 + jnp.exp(-(zx + zh)))
    n = jnp.tanh(nx + r * nh)
    h_new = (1.0 - z) * n + z * h
    assert h_new.shape[-1] == hidden
    return h_new
