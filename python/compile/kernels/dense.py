"""Pallas fused dense (+ activation) kernel — the encoder hot path.

TPU mapping (see DESIGN.md §8 (hardware mapping)): a dense layer
``y = act(x @ W + b)`` is tiled over columns of ``W`` so each grid step
computes one MXU-friendly ``(B, TILE_N)`` output block with the full ``x``
row block resident in VMEM.  The activation epilogue is fused into the same
block, so activations never round-trip to HBM between the matmul and the
non-linearity.  The whole START encoder (540→128→128→32, f32) is < 0.5 MB
of weights, far below the ~16 MB VMEM budget, so a single-pass schedule is
roofline-optimal and no HBM↔VMEM double-buffering is required.

On this CPU-only image the kernel must run with ``interpret=True`` — real
TPU lowering emits a Mosaic custom-call the CPU PJRT plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Column tile: one MXU lane-width worth of output features.
TILE_N = 128


def _dense_kernel(x_ref, w_ref, b_ref, o_ref, *, activation):
    """One (B, TILE_N) output block: fused matmul + bias + activation."""
    x = x_ref[...]
    w = w_ref[...]
    b = b_ref[...]
    # bf16 inputs accumulate in f32 on the MXU; mirror that here.
    y = jnp.dot(x, w, preferred_element_type=jnp.float32) + b.astype(jnp.float32)
    if activation == "softplus":
        y = jnp.logaddexp(y, 0.0)
    elif activation == "relu":
        y = jnp.maximum(y, 0.0)
    elif activation == "tanh":
        y = jnp.tanh(y)
    elif activation != "none":
        raise ValueError(f"unknown activation {activation!r}")
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("activation",))
def dense(x, w, b, activation="softplus"):
    """Fused ``act(x @ w + b)`` as a Pallas kernel.

    x: (B, IN), w: (IN, OUT), b: (OUT,) -> (B, OUT) in x.dtype.
    OUT is padded up to a multiple of TILE_N internally; callers see the
    exact shape.
    """
    batch, d_in = x.shape
    d_in_w, d_out = w.shape
    assert d_in == d_in_w, (x.shape, w.shape)
    assert b.shape == (d_out,)

    pad = (-d_out) % TILE_N
    if pad:
        w = jnp.pad(w, ((0, 0), (0, pad)))
        b = jnp.pad(b, (0, pad))
    n_pad = d_out + pad
    grid = (n_pad // TILE_N,)

    out = pl.pallas_call(
        functools.partial(_dense_kernel, activation=activation),
        grid=grid,
        in_specs=[
            # Full input row block every grid step.
            pl.BlockSpec((batch, d_in), lambda j: (0, 0)),
            # j-th column tile of the weights.
            pl.BlockSpec((d_in, TILE_N), lambda j: (0, j)),
            pl.BlockSpec((TILE_N,), lambda j: (j,)),
        ],
        out_specs=pl.BlockSpec((batch, TILE_N), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((batch, n_pad), x.dtype),
        interpret=True,
    )(x, w, b)
    return out[:, :d_out]


def vmem_bytes(batch, d_in, d_out, itemsize=4):
    """Per-grid-step VMEM footprint estimate for DESIGN.md §7."""
    n_tile = min(TILE_N, d_out)
    return itemsize * (batch * d_in + d_in * n_tile + n_tile + batch * n_tile)
