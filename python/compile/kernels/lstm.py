"""Pallas fused LSTM-cell kernel.

The paper's LSTM layers are 32-wide, so the classic MXU-utilization trick
applies: fuse the four gate matmuls into a single ``(IN, 4H)`` matmul (and
one ``(H, 4H)`` recurrent matmul) so the systolic array sees one wide GEMM
instead of four skinny ones, then run the elementwise gate epilogue
(sigmoid/tanh, Hadamard products) on the VPU inside the same block —
nothing spills to HBM between the GEMM and the state update.

Weights + state for a 32-unit cell are ~70 KB in f32: the entire cell fits
in VMEM in one block, so the grid is trivial (1,) and the BlockSpecs are
whole-array.  interpret=True is mandatory on CPU (see dense.py).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sigmoid(x):
    return 1.0 / (1.0 + jnp.exp(-x))


def _lstm_kernel(x_ref, h_ref, c_ref, wx_ref, wh_ref, b_ref, ho_ref, co_ref):
    x = x_ref[...]
    h = h_ref[...]
    c = c_ref[...]
    # One fused (B, 4H) gate GEMM pair.
    gates = (
        jnp.dot(x, wx_ref[...], preferred_element_type=jnp.float32)
        + jnp.dot(h, wh_ref[...], preferred_element_type=jnp.float32)
        + b_ref[...].astype(jnp.float32)
    )
    hidden = h.shape[-1]
    i = _sigmoid(gates[:, 0 * hidden : 1 * hidden])
    f = _sigmoid(gates[:, 1 * hidden : 2 * hidden])
    g = jnp.tanh(gates[:, 2 * hidden : 3 * hidden])
    o = _sigmoid(gates[:, 3 * hidden : 4 * hidden])
    c_new = f * c.astype(jnp.float32) + i * g
    h_new = o * jnp.tanh(c_new)
    ho_ref[...] = h_new.astype(ho_ref.dtype)
    co_ref[...] = c_new.astype(co_ref.dtype)


@jax.jit
def lstm_cell(x, h, c, wx, wh, b):
    """Fused LSTM cell: returns (h', c').

    x: (B, IN), h/c: (B, H), wx: (IN, 4H), wh: (H, 4H), b: (4H,).
    Gate order i, f, g, o (matches ref.lstm_cell_ref).
    """
    batch, d_in = x.shape
    hidden = h.shape[-1]
    assert wx.shape == (d_in, 4 * hidden), (wx.shape, (d_in, 4 * hidden))
    assert wh.shape == (hidden, 4 * hidden)
    assert b.shape == (4 * hidden,)
    assert c.shape == (batch, hidden)

    h_new, c_new = pl.pallas_call(
        _lstm_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((batch, hidden), h.dtype),
            jax.ShapeDtypeStruct((batch, hidden), c.dtype),
        ),
        interpret=True,
    )(x, h, c, wx, wh, b)
    return h_new, c_new


def vmem_bytes(batch, d_in, hidden, itemsize=4):
    """Whole-cell VMEM footprint estimate (single block)."""
    return itemsize * (
        batch * d_in
        + 2 * batch * hidden          # h, c in
        + d_in * 4 * hidden           # wx
        + hidden * 4 * hidden         # wh
        + 4 * hidden                  # b
        + batch * 4 * hidden          # gates scratch
        + 2 * batch * hidden          # h', c'
    )
