"""Pallas fused GRU-cell kernel — compute core of the IGRU-SD baseline.

Same fusion strategy as lstm.py: one wide ``(IN, 3H)`` input GEMM plus one
``(H, 3H)`` recurrent GEMM, elementwise r/z/n epilogue on the VPU in the
same VMEM block.  Note the GRU "new" gate needs the *ungated* recurrent
product ``h @ Wh_n`` (PyTorch convention), so the input and recurrent GEMMs
are kept separate rather than summed before the epilogue.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sigmoid(x):
    return 1.0 / (1.0 + jnp.exp(-x))


def _gru_kernel(x_ref, h_ref, wx_ref, wh_ref, b_ref, ho_ref):
    x = x_ref[...]
    h = h_ref[...]
    gx = jnp.dot(x, wx_ref[...], preferred_element_type=jnp.float32) + b_ref[
        ...
    ].astype(jnp.float32)
    gh = jnp.dot(h, wh_ref[...], preferred_element_type=jnp.float32)
    hidden = h.shape[-1]
    r = _sigmoid(gx[:, :hidden] + gh[:, :hidden])
    z = _sigmoid(gx[:, hidden : 2 * hidden] + gh[:, hidden : 2 * hidden])
    n = jnp.tanh(gx[:, 2 * hidden :] + r * gh[:, 2 * hidden :])
    h_new = (1.0 - z) * n + z * h.astype(jnp.float32)
    ho_ref[...] = h_new.astype(ho_ref.dtype)


@jax.jit
def gru_cell(x, h, wx, wh, b):
    """Fused GRU cell: returns h'.

    x: (B, IN), h: (B, H), wx: (IN, 3H), wh: (H, 3H), b: (3H,).
    Gate order r, z, n (matches ref.gru_cell_ref).
    """
    batch, d_in = x.shape
    hidden = h.shape[-1]
    assert wx.shape == (d_in, 3 * hidden)
    assert wh.shape == (hidden, 3 * hidden)
    assert b.shape == (3 * hidden,)

    return pl.pallas_call(
        _gru_kernel,
        out_shape=jax.ShapeDtypeStruct((batch, hidden), h.dtype),
        interpret=True,
    )(x, h, wx, wh, b)


def vmem_bytes(batch, d_in, hidden, itemsize=4):
    """Whole-cell VMEM footprint estimate (single block)."""
    return itemsize * (
        batch * d_in
        + batch * hidden
        + d_in * 3 * hidden
        + hidden * 3 * hidden
        + 3 * hidden
        + 2 * batch * 3 * hidden
        + batch * hidden
    )
