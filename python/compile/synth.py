"""Shared synthetic workload generative model (Python ↔ Rust contract).

The paper trains its Encoder-LSTM on PlanetLab-derived traces whose task
response times empirically follow a Pareto distribution whose parameters
depend on cluster state.  Those traces are not available offline, so we
define an explicit generative model (DESIGN.md §5):

    (α*, β*) = f(M_H, M_T)

mapping the normalized feature matrices to ground-truth Pareto parameters.
Heavier load / contention / heterogeneity → smaller α (heavier tail, more
stragglers); larger task demand and load → larger β (slower minimum time).

``true_pareto_params`` is mirrored *exactly* by
``rust/src/trace/generative.rs`` — the Rust simulator samples task
durations from the same distribution family, so the AOT-trained network is
evaluated in-distribution.  ``aot.py`` emits golden input/output pairs for
this function so the Rust mirror is pinned by tests.

All constants live in ``GEN`` and are serialized into
``artifacts/manifest.json``.
"""

import jax
import jax.numpy as jnp
import numpy as np

from . import dims

# Generative-model constants (serialized to the manifest; mirrored in Rust).
GEN = {
    "alpha_min": 1.15,
    "alpha_span": 2.85,
    "alpha_gain": 4.0,
    "alpha_mid": 0.65,
    "contention_weight": 0.5,
    "hetero_weight": 0.4,
    "beta_base": 1.0,
    "beta_demand_lo": 0.4,
    "beta_demand_w": 1.2,
    "beta_load_w": 0.8,
    "contention_knee": 1.2,
}

# M_H column indices (see dims.py docstring).
H_CPU_UTIL, H_RAM_UTIL, H_CPU_CAP, H_IS_UP = 0, 1, 4, 11
# M_T column indices.
T_CPU_REQ, T_ACTIVE = 0, 7


def true_pareto_params(m_h, m_t):
    """Ground-truth (α*, β*) for feature matrices.

    m_h: (..., N_HOSTS, M_FEATS), m_t: (..., Q_TASKS, P_FEATS).
    Returns (alpha, beta) with shape (...,).  Mirrored bit-for-bit by
    ``rust/src/trace/generative.rs`` (golden-tested).
    """
    up = m_h[..., H_IS_UP]
    n_up = jnp.maximum(up.sum(-1), 1e-6)
    # Mean CPU load over serviceable hosts.
    u = (m_h[..., H_CPU_UTIL] * up).sum(-1) / n_up
    # Contention: CPU+RAM pressure beyond the knee, averaged over up hosts.
    pressure = m_h[..., H_CPU_UTIL] + m_h[..., H_RAM_UTIL]
    c = (jnp.maximum(pressure - GEN["contention_knee"], 0.0) * up).sum(-1) / n_up
    # Capacity heterogeneity among serviceable hosts (population std).
    cap = m_h[..., H_CPU_CAP]
    cap_mean = (cap * up).sum(-1) / n_up
    cap_var = (((cap - cap_mean[..., None]) ** 2) * up).sum(-1) / n_up
    het = jnp.sqrt(jnp.maximum(cap_var, 0.0))
    # Mean demand of active task rows.
    act = m_t[..., T_ACTIVE]
    n_act = jnp.maximum(act.sum(-1), 1e-6)
    d = (m_t[..., T_CPU_REQ] * act).sum(-1) / n_act

    z = GEN["alpha_gain"] * (
        GEN["alpha_mid"]
        - u
        - GEN["contention_weight"] * c
        - GEN["hetero_weight"] * het * u
    )
    alpha = GEN["alpha_min"] + GEN["alpha_span"] / (1.0 + jnp.exp(-z))
    beta = (
        GEN["beta_base"]
        * (GEN["beta_demand_lo"] + GEN["beta_demand_w"] * d)
        * (1.0 + GEN["beta_load_w"] * u)
    )
    return alpha, beta


def pareto_mle(samples):
    """MLE fit (Eq. 2–3): β̂ = min(X), α̂ = q / Σ log(X_i / β̂).

    samples: (..., q).  Returns (alpha_hat, beta_hat).
    """
    beta = samples.min(-1)
    q = samples.shape[-1]
    denom = jnp.maximum(jnp.log(samples).sum(-1) - q * jnp.log(beta), 1e-6)
    alpha = q / denom
    return alpha, beta


def _ar1(key, shape, rho=0.85, sigma=0.1):
    """AR(1) sequence along axis 0 in [0, 1]-ish range."""
    t = shape[0]
    k0, k1 = jax.random.split(key)
    x0 = jax.random.uniform(k0, shape[1:])
    eps = sigma * jax.random.normal(k1, shape)

    def step(x, e):
        x = rho * x + (1 - rho) * 0.5 + e
        return x, x

    _, xs = jax.lax.scan(step, x0, eps)
    return jnp.clip(xs, 0.0, 1.0)


def random_feature_sequences(key, batch, steps=dims.ROLLOUT_STEPS):
    """Plausible (M_H, M_T) sequences with temporal correlation.

    Returns m_h_seq (T, B, N_HOSTS, M_FEATS) and m_t_seq (T, B, Q_TASKS,
    P_FEATS), already EMA-smoothed the way the Rust feature extractor
    smooths real matrices (weight 0.8 on the latest).
    """
    ks = jax.random.split(key, 8)
    t, b = steps, batch

    # Host utilizations: AR(1) per host, shared load regime per batch elem.
    regime = jax.random.uniform(ks[0], (1, b, 1), minval=0.1, maxval=0.9)
    util = _ar1(ks[1], (t, b, dims.N_HOSTS, 4), rho=0.85, sigma=0.08)
    util = jnp.clip(0.6 * util + 0.55 * regime[..., None], 0.0, 1.0)

    # Static host capacities / power / cost; sampled per batch element.
    caps = jax.random.uniform(ks[2], (1, b, dims.N_HOSTS, 6), minval=0.15, maxval=1.0)
    caps = jnp.broadcast_to(caps, (t, b, dims.N_HOSTS, 6))
    ntasks = _ar1(ks[3], (t, b, dims.N_HOSTS, 1), rho=0.9, sigma=0.05)
    is_up = (
        jax.random.uniform(ks[4], (t, b, dims.N_HOSTS, 1)) > 0.05
    ).astype(jnp.float32)
    m_h = jnp.concatenate([util, caps, ntasks, is_up], axis=-1)

    # Task rows: requirements + flags; a random prefix of rows is active.
    reqs = _ar1(ks[5], (t, b, dims.Q_TASKS, 5), rho=0.9, sigma=0.05)
    flags = (jax.random.uniform(ks[6], (1, b, dims.Q_TASKS, 2)) > 0.5).astype(
        jnp.float32
    )
    flags = jnp.broadcast_to(flags, (t, b, dims.Q_TASKS, 2))
    q_active = jax.random.randint(ks[7], (1, b, 1), 2, dims.Q_TASKS + 1)
    row = jnp.arange(dims.Q_TASKS)[None, None, :]
    active = (row < q_active).astype(jnp.float32)
    active = jnp.broadcast_to(active, (t, b, dims.Q_TASKS))[..., None]
    m_t = jnp.concatenate([reqs, flags[..., :1], flags[..., 1:] * 0.0, active], axis=-1)
    m_t = m_t * active  # zero-pad inactive rows entirely

    # EMA smoothing (weight on latest = dims.EMA_WEIGHT), as in Rust.
    def ema_step(prev, cur):
        sm = dims.EMA_WEIGHT * cur + (1.0 - dims.EMA_WEIGHT) * prev
        return sm, sm

    _, m_h_s = jax.lax.scan(ema_step, m_h[0], m_h)
    _, m_t_s = jax.lax.scan(ema_step, m_t[0], m_t)
    return m_h_s, m_t_s


def make_dataset_jax(key, n, steps=dims.ROLLOUT_STEPS, q_fit=64):
    """Jit-friendly core of make_dataset: returns jnp arrays.

    Labels are the *MLE-fitted* (α̂, β̂) from ``q_fit`` task-time samples of
    the ground-truth distribution at the window end — matching the paper's
    procedure (fit Eq. 3 on observed response times, regress with MSE).
    """
    k1, k2 = jax.random.split(key)
    m_h_seq, m_t_seq = random_feature_sequences(k1, n, steps)
    alpha_t, beta_t = true_pareto_params(m_h_seq[-1], m_t_seq[-1])
    # Sample task times X = β U^{-1/α} and fit.
    u = jax.random.uniform(k2, (n, q_fit), minval=1e-6, maxval=1.0)
    x = beta_t[:, None] * u ** (-1.0 / alpha_t[:, None])
    alpha_l, beta_l = pareto_mle(x)
    return {
        "m_h_seq": m_h_seq,
        "m_t_seq": m_t_seq,
        "alpha": alpha_l,
        "beta": beta_l,
        "alpha_true": alpha_t,
        "beta_true": beta_t,
    }


def make_dataset(key, n, steps=dims.ROLLOUT_STEPS, q_fit=64):
    """Training set for the Encoder-LSTM (numpy view of make_dataset_jax)."""
    return {k: np.asarray(v) for k, v in make_dataset_jax(key, n, steps, q_fit).items()}


def make_igru_dataset(key, n, steps=dims.ROLLOUT_STEPS + 1):
    """Training set for the IGRU-SD baseline: predict next-step CPU demand.

    Returns (m_t_seq (T,B,Q,P), target (B, Q_TASKS)) where target is the
    CPU-requirement column at the final step and the network sees steps
    0..T-2.
    """
    _, m_t_seq = random_feature_sequences(key, n, steps)
    target = m_t_seq[-1][..., T_CPU_REQ]
    return np.asarray(m_t_seq[:-1]), np.asarray(target)
