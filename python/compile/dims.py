"""Shared dimension / feature-layout constants for the START model stack.

These constants are the single source of truth for the AOT interchange
shapes.  `aot.py` serializes them into ``artifacts/manifest.json`` and the
Rust coordinator (``rust/src/runtime/manifest.rs``) reads them back, so the
feature vectors built on the Rust side line up bit-for-bit with what the
network was trained on.

Feature layouts (all values normalized to roughly [0, 1]):

``M_H`` row (one per physical host slot, ``M_FEATS`` = 12)::

    0  cpu_util      fraction of host MIPS in use
    1  ram_util      fraction of host RAM in use
    2  disk_util     fraction of host disk in use
    3  bw_util       fraction of host bandwidth in use
    4  cpu_cap       host MIPS / max MIPS in the fleet
    5  ram_cap       host RAM / max RAM
    6  disk_cap      host disk / max disk
    7  bw_cap        host bandwidth / max bandwidth
    8  power_frac    (P_max - P_min) / global max spread
    9  cost_frac     $/interval, normalized
    10 n_tasks_frac  active tasks on host / Q_TASKS
    11 is_up         1.0 if the host is serviceable, else 0.0

``M_T`` row (one per task slot of the job under prediction, ``P_FEATS`` = 8)::

    0  cpu_req       task MIPS demand / host max MIPS
    1  ram_req       task RAM demand / host max RAM
    2  disk_req      task disk demand / host max disk
    3  bw_req        task bandwidth demand / host max bandwidth
    4  prev_host     host index the task ran on last interval / N_HOSTS
    5  deadline      1.0 if the job is deadline-driven
    6  progress      fraction of the task's work completed
    7  active        1.0 for a real task row, 0.0 for zero-padding
"""

# Host-matrix shape (paper: n hosts x m features).
N_HOSTS = 20
M_FEATS = 12

# Task-matrix shape (paper: q' = max tasks per job, p features).
Q_TASKS = 10
P_FEATS = 8

# Encoder: |M_H| + |M_T| -> 128 -> 128 -> 32 (softplus, Sec. 3.2).
ENC_IN = N_HOSTS * M_FEATS + Q_TASKS * P_FEATS
ENC_H1 = 128
ENC_H2 = 128
ENC_OUT = 32

# Two stacked LSTM layers of 32 units (Sec. 3.2).
HIDDEN = 32

# Pareto head: 32 -> 2 ((alpha, beta) after ReLU; +1 on alpha).
HEAD_OUT = 2

# START inference cadence (Sec. 3.2, grid-searched in Fig. 2).
INFER_PERIOD_S = 1.0   # I
INFER_WINDOW_S = 5.0   # T
EMA_WEIGHT = 0.8       # weight on the latest resource matrix
K_DEFAULT = 1.5        # straggler parameter multiple of the mean

ROLLOUT_STEPS = 5      # T / I

# IGRU-SD baseline: GRU over the flattened task matrix.
IGRU_IN = Q_TASKS * P_FEATS
IGRU_HIDDEN = 32
IGRU_OUT = Q_TASKS     # predicted next-interval CPU demand per task slot
