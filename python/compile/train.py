"""Build-time training of the START Encoder-LSTM and the IGRU-SD baseline.

Runs once under ``make artifacts`` (cached in ``artifacts/weights.npz``).
Matches the paper's §4.4: MSE loss between the network's (α, β) and the
MLE fit of observed task response times, Adam optimizer.  The paper quotes
lr = 1e-5 for its multi-week trace corpus; on our synthetic corpus the
same schedule converges with lr = 1e-3 and ~1.5k steps (documented in
DESIGN.md §7).

Adam is implemented by hand — no optax on this image.
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import dims, model, synth

# Training differentiates through the model; interpret-mode Pallas has no
# reverse-mode autodiff, so route through the jnp reference ops (identical
# numerics, pinned by tests/test_kernel.py).
model.set_impl(use_pallas=False)

# --------------------------------------------------------------------------
# Minimal Adam (optax is unavailable offline)
# --------------------------------------------------------------------------


def adam_init(params):
    zeros = {k: jnp.zeros_like(v) for k, v in params.items()}
    return {"m": zeros, "v": {k: jnp.zeros_like(v) for k, v in params.items()}, "t": jnp.zeros(())}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1.0
    m = {k: b1 * state["m"][k] + (1 - b1) * grads[k] for k in params}
    v = {k: b2 * state["v"][k] + (1 - b2) * grads[k] ** 2 for k in params}
    mhat = {k: m[k] / (1 - b1**t) for k in params}
    vhat = {k: v[k] / (1 - b2**t) for k in params}
    new = {k: params[k] - lr * mhat[k] / (jnp.sqrt(vhat[k]) + eps) for k in params}
    return new, {"m": m, "v": v, "t": t}


# --------------------------------------------------------------------------
# START training
# --------------------------------------------------------------------------


def start_loss(params, m_h_seq, m_t_seq, alpha_l, beta_l):
    """MSE between rollout (α, β) and MLE labels (paper §4.4)."""
    alpha, beta = model.start_rollout(params, m_h_seq, m_t_seq)
    return jnp.mean((alpha - alpha_l) ** 2 + (beta - beta_l) ** 2)


def train_start(key, steps=1500, batch=128, lr=3e-3, log_every=150, log=print):
    """Train the Encoder-LSTM; returns (params, history).

    Data synthesis + grad + Adam update are fused under a single jit so the
    per-step cost is milliseconds after the first compile.
    """
    # Re-assert the differentiable impl: another module (e.g. the AOT path
    # or a test) may have switched the process-global impl to Pallas.
    model.set_impl(use_pallas=False)
    kp, kd = jax.random.split(key)
    params = model.init_start_params(kp)
    opt = adam_init(params)

    @jax.jit
    def train_step(params, opt, key):
        ds = synth.make_dataset_jax(key, batch)
        loss, grads = jax.value_and_grad(start_loss)(
            params, ds["m_h_seq"], ds["m_t_seq"], ds["alpha"], ds["beta"]
        )
        params, opt = adam_update(params, grads, opt, lr)
        return params, opt, loss

    history = []
    t0 = time.time()
    for step in range(steps):
        kd, kb = jax.random.split(kd)
        params, opt, loss = train_step(params, opt, kb)
        if step % log_every == 0 or step == steps - 1:
            history.append((step, float(loss)))
            log(f"[train start] step {step:5d} loss {float(loss):.5f} ({time.time()-t0:.1f}s)")
    return params, history


# --------------------------------------------------------------------------
# IGRU-SD training
# --------------------------------------------------------------------------


def igru_loss(params, m_t_seq, target):
    def body(h, m_t):
        pred, h = model.igru_step(params, m_t, h)
        return h, pred

    h0 = jnp.zeros((m_t_seq.shape[1], dims.IGRU_HIDDEN), jnp.float32)
    _, preds = jax.lax.scan(body, h0, m_t_seq)
    return jnp.mean((preds[-1] - target) ** 2)


def train_igru(key, steps=800, batch=128, lr=3e-3, log_every=100, log=print):
    model.set_impl(use_pallas=False)
    kp, kd = jax.random.split(key)
    params = model.init_igru_params(kp)
    opt = adam_init(params)

    @jax.jit
    def train_step(params, opt, key):
        steps_t = dims.ROLLOUT_STEPS + 1
        _, m_t_seq = synth.random_feature_sequences(key, batch, steps_t)
        target = m_t_seq[-1][..., synth.T_CPU_REQ]
        loss, grads = jax.value_and_grad(igru_loss)(params, m_t_seq[:-1], target)
        params, opt = adam_update(params, grads, opt, lr)
        return params, opt, loss

    history = []
    t0 = time.time()
    for step in range(steps):
        kd, kb = jax.random.split(kd)
        params, opt, loss = train_step(params, opt, kb)
        if step % log_every == 0 or step == steps - 1:
            history.append((step, float(loss)))
            log(f"[train igru ] step {step:5d} loss {float(loss):.5f} ({time.time()-t0:.1f}s)")
    return params, history


# --------------------------------------------------------------------------
# Weight persistence
# --------------------------------------------------------------------------


def save_weights(path, start_params, igru_params):
    flat = {f"start.{k}": np.asarray(v) for k, v in start_params.items()}
    flat.update({f"igru.{k}": np.asarray(v) for k, v in igru_params.items()})
    np.savez(path, **flat)


def load_weights(path):
    data = np.load(path)
    start_params = {
        k[len("start.") :]: jnp.asarray(data[k]) for k in data.files if k.startswith("start.")
    }
    igru_params = {
        k[len("igru.") :]: jnp.asarray(data[k]) for k in data.files if k.startswith("igru.")
    }
    return start_params, igru_params


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts/weights.npz")
    ap.add_argument("--steps", type=int, default=1500)
    ap.add_argument("--igru-steps", type=int, default=800)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    key = jax.random.PRNGKey(args.seed)
    k1, k2 = jax.random.split(key)
    start_params, _ = train_start(k1, steps=args.steps)
    igru_params, _ = train_igru(k2, steps=args.igru_steps)
    save_weights(args.out, start_params, igru_params)
    print(f"saved weights to {args.out}")


if __name__ == "__main__":
    main()
