"""L2: the START Encoder-LSTM model and the IGRU-SD baseline model.

Faithful to paper §3.2:

* Encoder — 4 fully-connected layers with softplus activations:
  input ``|M_H| + |M_T|`` → 128 → 128 → 32 (the input "layer" is the
  flatten+concat of the two feature matrices).
* LSTM — 2 stacked layers, 32 units each.  The cell consumes the encoder
  output λ and the previous hidden state η_{t−1}: η_t = LSTM(η_{t−1}, λ).
* Head — fully-connected 32 → 2, ReLU so (α, β) are positive, +1 on α so
  the Pareto mean is defined (α > 1).

All matmuls route through the Pallas kernels in ``kernels/`` so the AOT
HLO exercises the L1 layer.  The exponential-moving-average smoothing of
the input matrices (weight 0.8 on the latest matrix) is applied by the
Rust feature extractor, which owns the history; the model sees smoothed
matrices.

Also defined here: the IGRU-SD baseline network (GRU over the flattened
task matrix, predicting next-interval per-task CPU demand), used by the
``baselines/igru`` module on the Rust side.
"""

import jax
import jax.numpy as jnp

from . import dims
from .kernels import ref
from .kernels.dense import dense as _dense_pallas
from .kernels.gru import gru_cell as _gru_pallas
from .kernels.lstm import lstm_cell as _lstm_pallas

# Implementation switch: the Pallas kernels run under interpret=True, which
# does not support reverse-mode autodiff, so training (train.py) routes
# through the pure-jnp reference ops (bit-compatible — pinned by
# tests/test_kernel.py) while AOT lowering uses the Pallas kernels.
_USE_PALLAS = True


def set_impl(use_pallas: bool):
    """Select kernel implementation: Pallas (AOT path) or ref (training)."""
    global _USE_PALLAS
    _USE_PALLAS = use_pallas


def dense(x, w, b, activation="softplus"):
    if _USE_PALLAS:
        return _dense_pallas(x, w, b, activation=activation)
    return ref.dense_ref(x, w, b, activation=activation)


def lstm_cell(x, h, c, wx, wh, b):
    if _USE_PALLAS:
        return _lstm_pallas(x, h, c, wx, wh, b)
    return ref.lstm_cell_ref(x, h, c, wx, wh, b)


def gru_cell(x, h, wx, wh, b):
    if _USE_PALLAS:
        return _gru_pallas(x, h, wx, wh, b)
    return ref.gru_cell_ref(x, h, wx, wh, b)

# --------------------------------------------------------------------------
# Parameter initialization
# --------------------------------------------------------------------------


def _glorot(key, shape):
    fan_in, fan_out = shape[0], shape[-1]
    scale = jnp.sqrt(2.0 / (fan_in + fan_out))
    return scale * jax.random.normal(key, shape, dtype=jnp.float32)


def init_start_params(key):
    """Initialize Encoder-LSTM parameters as a flat dict of arrays."""
    ks = jax.random.split(key, 12)
    p = {
        # Encoder MLP.
        "enc_w1": _glorot(ks[0], (dims.ENC_IN, dims.ENC_H1)),
        "enc_b1": jnp.zeros((dims.ENC_H1,), jnp.float32),
        "enc_w2": _glorot(ks[1], (dims.ENC_H1, dims.ENC_H2)),
        "enc_b2": jnp.zeros((dims.ENC_H2,), jnp.float32),
        "enc_w3": _glorot(ks[2], (dims.ENC_H2, dims.ENC_OUT)),
        "enc_b3": jnp.zeros((dims.ENC_OUT,), jnp.float32),
        # LSTM layer 1 (input = encoder output).
        "lstm1_wx": _glorot(ks[3], (dims.ENC_OUT, 4 * dims.HIDDEN)),
        "lstm1_wh": _glorot(ks[4], (dims.HIDDEN, 4 * dims.HIDDEN)),
        "lstm1_b": jnp.zeros((4 * dims.HIDDEN,), jnp.float32),
        # LSTM layer 2.
        "lstm2_wx": _glorot(ks[5], (dims.HIDDEN, 4 * dims.HIDDEN)),
        "lstm2_wh": _glorot(ks[6], (dims.HIDDEN, 4 * dims.HIDDEN)),
        "lstm2_b": jnp.zeros((4 * dims.HIDDEN,), jnp.float32),
        # (α, β) head.  Bias starts at 0.5 so the ReLU head begins in its
        # active region (a zero init leaves half the gradient paths dead).
        "head_w": _glorot(ks[7], (dims.HIDDEN, dims.HEAD_OUT)),
        "head_b": 0.5 * jnp.ones((dims.HEAD_OUT,), jnp.float32),
    }
    # Forget-gate bias = 1.0: standard LSTM trainability trick.
    for name in ("lstm1_b", "lstm2_b"):
        b = p[name]
        p[name] = b.at[dims.HIDDEN : 2 * dims.HIDDEN].set(1.0)
    return p


def init_igru_params(key):
    """Initialize the IGRU-SD baseline GRU parameters."""
    ks = jax.random.split(key, 4)
    return {
        "gru_wx": _glorot(ks[0], (dims.IGRU_IN, 3 * dims.IGRU_HIDDEN)),
        "gru_wh": _glorot(ks[1], (dims.IGRU_HIDDEN, 3 * dims.IGRU_HIDDEN)),
        "gru_b": jnp.zeros((3 * dims.IGRU_HIDDEN,), jnp.float32),
        "out_w": _glorot(ks[2], (dims.IGRU_HIDDEN, dims.IGRU_OUT)),
        "out_b": jnp.zeros((dims.IGRU_OUT,), jnp.float32),
    }


def zero_state(batch=1):
    """Initial LSTM state η_0 = 0 (paper §3.2)."""
    z = jnp.zeros((batch, dims.HIDDEN), jnp.float32)
    return (z, z, z, z)  # h1, c1, h2, c2


# --------------------------------------------------------------------------
# START Encoder-LSTM
# --------------------------------------------------------------------------


def encoder(params, m_h, m_t):
    """Encoder MLP over flattened, concatenated feature matrices.

    m_h: (B, N_HOSTS, M_FEATS), m_t: (B, Q_TASKS, P_FEATS) -> (B, ENC_OUT)
    """
    batch = m_h.shape[0]
    x = jnp.concatenate(
        [m_h.reshape(batch, -1), m_t.reshape(batch, -1)], axis=-1
    )
    # The paper applies softplus at the input layer too.
    x = jnp.logaddexp(x, 0.0)
    x = dense(x, params["enc_w1"], params["enc_b1"], activation="softplus")
    x = dense(x, params["enc_w2"], params["enc_b2"], activation="softplus")
    x = dense(x, params["enc_w3"], params["enc_b3"], activation="softplus")
    return x


def start_step(params, m_h, m_t, state):
    """One START inference tick: (α, β) estimate + next LSTM state.

    Returns ((B,) alpha, (B,) beta, state').  alpha > 1, beta >= 0.
    """
    h1, c1, h2, c2 = state
    lam = encoder(params, m_h, m_t)
    h1, c1 = lstm_cell(lam, h1, c1, params["lstm1_wx"], params["lstm1_wh"], params["lstm1_b"])
    h2, c2 = lstm_cell(h1, h2, c2, params["lstm2_wx"], params["lstm2_wh"], params["lstm2_b"])
    out = dense(h2, params["head_w"], params["head_b"], activation="relu")
    alpha = out[:, 0] + 1.0 + 1e-3  # +1 so the Pareto mean is defined
    beta = out[:, 1] + 1e-3         # strictly positive minimum time
    return alpha, beta, (h1, c1, h2, c2)


def start_rollout(params, m_h_seq, m_t_seq):
    """Fused T-step rollout: scan start_step over the window, from η_0 = 0.

    m_h_seq: (T, B, N_HOSTS, M_FEATS), m_t_seq: (T, B, Q_TASKS, P_FEATS).
    Returns the (α, β) estimate after the final step.  This is the single
    PJRT dispatch the Rust hot path uses (1 call instead of T).
    """
    batch = m_h_seq.shape[1]

    def body(state, inputs):
        m_h, m_t = inputs
        alpha, beta, state = start_step(params, m_h, m_t, state)
        return state, (alpha, beta)

    state, (alphas, betas) = jax.lax.scan(
        body, zero_state(batch), (m_h_seq, m_t_seq)
    )
    del state
    return alphas[-1], betas[-1]


# --------------------------------------------------------------------------
# IGRU-SD baseline network
# --------------------------------------------------------------------------


def igru_step(params, m_t, h):
    """One IGRU-SD tick: predicted next-interval per-task CPU demand.

    m_t: (B, Q_TASKS, P_FEATS), h: (B, IGRU_HIDDEN).
    Returns ((B, Q_TASKS) preds in [0, inf), h').
    """
    batch = m_t.shape[0]
    x = m_t.reshape(batch, -1)
    h = gru_cell(x, h, params["gru_wx"], params["gru_wh"], params["gru_b"])
    pred = dense(h, params["out_w"], params["out_b"], activation="relu")
    return pred, h
