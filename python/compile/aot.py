"""AOT compile path: train/load weights, bake, lower to HLO **text**.

Interchange format is HLO text, NOT ``lowered.compile().serialize()``:
jax ≥ 0.5 emits HloModuleProtos with 64-bit instruction ids which the
``xla`` crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Outputs (all under ``artifacts/``):

* ``start_step.hlo.txt``          (m_h, m_t, h1, c1, h2, c2) → (α, β, h1', c1', h2', c2')
* ``start_rollout.hlo.txt``       (m_h_seq, m_t_seq) → (α, β)      [B = 1]
* ``start_rollout_b8.hlo.txt``    batched rollout                  [B = 8]
* ``igru_step.hlo.txt``           (m_t, h) → (pred, h')
* ``manifest.json``               shapes + constants + artifact index
* ``golden.json``                 pinned inputs/outputs for Rust parity tests
* ``weights.npz``                 trained parameters (cache)

Weights are baked into the computation as constants, so the Rust hot path
marshals only the feature matrices.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import dims, model, synth, train

# train.py selects the differentiable jnp reference impl at import time;
# the AOT artifacts must exercise the L1 Pallas kernels.
model.set_impl(use_pallas=True)


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the 0.5.1-safe interchange).

    ``print_large_constants=True`` is load-bearing: the baked weight
    matrices must survive the text round-trip (the default elides anything
    big as ``constant({...})``, which the Rust-side parser cannot restore).
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def _spec(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def build_closures(start_params, igru_params):
    """Bind trained weights as constants; return name → (fn, arg_specs)."""
    B = 1
    T = dims.ROLLOUT_STEPS
    mh = (B, dims.N_HOSTS, dims.M_FEATS)
    mt = (B, dims.Q_TASKS, dims.P_FEATS)
    hid = (B, dims.HIDDEN)

    def start_step_fn(m_h, m_t, h1, c1, h2, c2):
        alpha, beta, (h1, c1, h2, c2) = model.start_step(
            start_params, m_h, m_t, (h1, c1, h2, c2)
        )
        return alpha, beta, h1, c1, h2, c2

    def rollout_fn(m_h_seq, m_t_seq):
        return model.start_rollout(start_params, m_h_seq, m_t_seq)

    def igru_fn(m_t, h):
        return model.igru_step(igru_params, m_t, h)

    B8 = 8
    return {
        "start_step": (
            start_step_fn,
            (_spec(mh), _spec(mt), _spec(hid), _spec(hid), _spec(hid), _spec(hid)),
        ),
        "start_rollout": (
            rollout_fn,
            (_spec((T,) + mh), _spec((T,) + mt)),
        ),
        "start_rollout_b8": (
            rollout_fn,
            (
                _spec((T, B8, dims.N_HOSTS, dims.M_FEATS)),
                _spec((T, B8, dims.Q_TASKS, dims.P_FEATS)),
            ),
        ),
        "igru_step": (
            igru_fn,
            (_spec(mt), _spec((B, dims.IGRU_HIDDEN))),
        ),
    }


def emit_golden(closures, out_dir):
    """Pinned input/output vectors so Rust can verify PJRT numerics parity,
    plus generative-model goldens pinning trace/generative.rs to synth.py."""
    golden = {}
    key = jax.random.PRNGKey(42)
    for name, (fn, specs) in closures.items():
        key, *ks = jax.random.split(key, len(specs) + 1)
        args = [
            jax.random.uniform(k, s.shape, dtype=s.dtype) for k, s in zip(ks, specs)
        ]
        outs = jax.jit(fn)(*args)
        if not isinstance(outs, (tuple, list)):
            outs = (outs,)
        golden[name] = {
            "inputs": [np.asarray(a).ravel().tolist() for a in args],
            "input_shapes": [list(s.shape) for s in specs],
            "outputs": [np.asarray(o).ravel().tolist() for o in outs],
            "output_shapes": [list(np.asarray(o).shape) for o in outs],
        }

    # Generative-model parity pins (feature matrices → α*, β*).
    kf = jax.random.PRNGKey(7)
    m_h_seq, m_t_seq = synth.random_feature_sequences(kf, 8)
    alpha, beta = synth.true_pareto_params(m_h_seq[-1], m_t_seq[-1])
    golden["generative"] = {
        "m_h": np.asarray(m_h_seq[-1]).ravel().tolist(),
        "m_t": np.asarray(m_t_seq[-1]).ravel().tolist(),
        "batch": 8,
        "alpha": np.asarray(alpha).tolist(),
        "beta": np.asarray(beta).tolist(),
    }
    with open(os.path.join(out_dir, "golden.json"), "w") as f:
        json.dump(golden, f)


def emit_manifest(out_dir, artifacts):
    manifest = {
        "n_hosts": dims.N_HOSTS,
        "m_feats": dims.M_FEATS,
        "q_tasks": dims.Q_TASKS,
        "p_feats": dims.P_FEATS,
        "hidden": dims.HIDDEN,
        "igru_hidden": dims.IGRU_HIDDEN,
        "rollout_steps": dims.ROLLOUT_STEPS,
        "rollout_batch": 8,
        "ema_weight": dims.EMA_WEIGHT,
        "k_default": dims.K_DEFAULT,
        "infer_period_s": dims.INFER_PERIOD_S,
        "infer_window_s": dims.INFER_WINDOW_S,
        "generative": synth.GEN,
        "artifacts": artifacts,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--retrain", action="store_true")
    ap.add_argument("--train-steps", type=int, default=1500)
    ap.add_argument("--igru-steps", type=int, default=800)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    weights_path = os.path.join(args.out_dir, "weights.npz")
    if args.retrain or not os.path.exists(weights_path):
        key = jax.random.PRNGKey(args.seed)
        k1, k2 = jax.random.split(key)
        start_params, _ = train.train_start(k1, steps=args.train_steps)
        igru_params, _ = train.train_igru(k2, steps=args.igru_steps)
        train.save_weights(weights_path, start_params, igru_params)
        print(f"trained + saved weights → {weights_path}")
    else:
        start_params, igru_params = train.load_weights(weights_path)
        print(f"loaded cached weights ← {weights_path}")

    closures = build_closures(start_params, igru_params)
    artifacts = {}
    for name, (fn, specs) in closures.items():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        artifacts[name] = fname
        print(f"lowered {name:18s} → {fname} ({len(text)} chars)")

    emit_golden(closures, args.out_dir)
    emit_manifest(args.out_dir, artifacts)
    print("wrote manifest.json + golden.json")


if __name__ == "__main__":
    main()
