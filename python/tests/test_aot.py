"""AOT path: lowering produces parseable HLO text with the expected
parameter/result shapes, and the manifest/golden files are consistent.

Runs against a freshly-initialized (untrained) model so the test is cheap
and independent of ``make artifacts``.
"""

import json
import os

import jax
import pytest

from compile import aot, dims, model

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.fixture(scope="module")
def closures():
    sp = model.init_start_params(jax.random.PRNGKey(0))
    ip = model.init_igru_params(jax.random.PRNGKey(1))
    return aot.build_closures(sp, ip)


def test_lowering_produces_hlo_text(closures):
    fn, specs = closures["start_step"]
    lowered = jax.jit(fn).lower(*specs)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ENTRY" in text
    # 6 parameters: m_h, m_t, h1, c1, h2, c2.
    assert text.count("parameter(") >= 6
    # matmuls from the encoder/lstm survive to HLO.
    assert "dot(" in text or "dot." in text


def test_rollout_lowering_contains_loop_or_unroll(closures):
    fn, specs = closures["start_rollout"]
    lowered = jax.jit(fn).lower(*specs)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    # scan lowers to a while loop (or is fully unrolled for T=5).
    assert ("while" in text) or text.count("dot") >= 5 * 3


def test_closure_shapes(closures):
    _, specs = closures["start_step"]
    assert tuple(specs[0].shape) == (1, dims.N_HOSTS, dims.M_FEATS)
    assert tuple(specs[1].shape) == (1, dims.Q_TASKS, dims.P_FEATS)
    _, specs = closures["start_rollout_b8"]
    assert tuple(specs[0].shape) == (dims.ROLLOUT_STEPS, 8, dims.N_HOSTS, dims.M_FEATS)
    _, specs = closures["igru_step"]
    assert tuple(specs[1].shape) == (1, dims.IGRU_HIDDEN)


def test_closures_execute(closures):
    """Each baked closure runs under jit and returns finite outputs."""
    import numpy as np

    key = jax.random.PRNGKey(3)
    for name, (fn, specs) in closures.items():
        key, *ks = jax.random.split(key, len(specs) + 1)
        args = [jax.random.uniform(k, s.shape, dtype=s.dtype) for k, s in zip(ks, specs)]
        outs = jax.jit(fn)(*args)
        if not isinstance(outs, (tuple, list)):
            outs = (outs,)
        for o in outs:
            assert np.all(np.isfinite(np.asarray(o))), name


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART_DIR, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
class TestBuiltArtifacts:
    def test_manifest_consistent(self):
        with open(os.path.join(ART_DIR, "manifest.json")) as f:
            m = json.load(f)
        assert m["n_hosts"] == dims.N_HOSTS
        assert m["q_tasks"] == dims.Q_TASKS
        assert m["rollout_steps"] == dims.ROLLOUT_STEPS
        for fname in m["artifacts"].values():
            path = os.path.join(ART_DIR, fname)
            assert os.path.exists(path), fname
            with open(path) as f:
                head = f.read(4096)
            assert "HloModule" in head

    def test_golden_exists_and_shapes(self):
        with open(os.path.join(ART_DIR, "golden.json")) as f:
            g = json.load(f)
        step = g["start_step"]
        assert len(step["inputs"]) == 6
        assert len(step["outputs"]) == 6
        n = dims.N_HOSTS * dims.M_FEATS
        assert len(step["inputs"][0]) == n
        gen = g["generative"]
        assert len(gen["alpha"]) == gen["batch"]
