"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes and dtypes; assert_allclose against ref.  This is
the CORE correctness signal for the compute layer — everything the Rust
binary executes via PJRT is built from these three kernels.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import ref
from compile.kernels.dense import dense
from compile.kernels.gru import gru_cell
from compile.kernels.lstm import lstm_cell

ACTIVATIONS = ["softplus", "relu", "tanh", "none"]


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, dtype=jnp.float32)
    return x.astype(dtype)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------
# dense
# --------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(
    batch=st.integers(1, 8),
    d_in=st.integers(1, 300),
    d_out=st.integers(1, 300),
    act=st.sampled_from(ACTIVATIONS),
    seed=st.integers(0, 2**31 - 1),
)
def test_dense_matches_ref(batch, d_in, d_out, act, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = _rand(ks[0], (batch, d_in), jnp.float32)
    w = _rand(ks[1], (d_in, d_out), jnp.float32) * 0.1
    b = _rand(ks[2], (d_out,), jnp.float32) * 0.1
    got = dense(x, w, b, activation=act)
    want = ref.dense_ref(x, w, b, activation=act)
    assert got.shape == (batch, d_out)
    assert got.dtype == x.dtype
    assert_allclose(np.asarray(got), np.asarray(want), **_tol(jnp.float32))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("act", ACTIVATIONS)
def test_dense_dtypes(dtype, act):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    x = _rand(ks[0], (2, 64), dtype)
    w = _rand(ks[1], (64, 96), dtype) * 0.1
    b = _rand(ks[2], (96,), dtype) * 0.1
    got = dense(x, w, b, activation=act)
    want = ref.dense_ref(
        x.astype(jnp.float32), w.astype(jnp.float32), b.astype(jnp.float32), activation=act
    )
    assert got.dtype == dtype
    assert_allclose(
        np.asarray(got, dtype=np.float32), np.asarray(want), **_tol(dtype)
    )


def test_dense_exact_tile_boundary():
    """d_out exactly TILE_N and a multiple of it — no padding path."""
    for d_out in (128, 256):
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        x = _rand(ks[0], (1, 540), jnp.float32)
        w = _rand(ks[1], (540, d_out), jnp.float32) * 0.05
        b = jnp.zeros((d_out,))
        got = dense(x, w, b, activation="softplus")
        want = ref.dense_ref(x, w, b, activation="softplus")
        assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_dense_rejects_bad_activation():
    x = jnp.zeros((1, 4))
    w = jnp.zeros((4, 4))
    b = jnp.zeros((4,))
    with pytest.raises(ValueError):
        dense(x, w, b, activation="gelu")


# --------------------------------------------------------------------------
# lstm_cell
# --------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(
    batch=st.integers(1, 8),
    d_in=st.integers(1, 64),
    hidden=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_lstm_matches_ref(batch, d_in, hidden, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    x = _rand(ks[0], (batch, d_in), jnp.float32)
    h = _rand(ks[1], (batch, hidden), jnp.float32)
    c = _rand(ks[2], (batch, hidden), jnp.float32)
    wx = _rand(ks[3], (d_in, 4 * hidden), jnp.float32) * 0.2
    wh = _rand(ks[4], (hidden, 4 * hidden), jnp.float32) * 0.2
    b = _rand(ks[5], (4 * hidden,), jnp.float32) * 0.2
    h2, c2 = lstm_cell(x, h, c, wx, wh, b)
    hr, cr = ref.lstm_cell_ref(x, h, c, wx, wh, b)
    assert h2.shape == (batch, hidden) and c2.shape == (batch, hidden)
    assert_allclose(np.asarray(h2), np.asarray(hr), rtol=1e-5, atol=1e-5)
    assert_allclose(np.asarray(c2), np.asarray(cr), rtol=1e-5, atol=1e-5)


def test_lstm_state_bounded():
    """|h| <= 1 always (tanh(c) * sigmoid gate)."""
    ks = jax.random.split(jax.random.PRNGKey(3), 6)
    x = 10.0 * _rand(ks[0], (4, 32), jnp.float32)
    h = _rand(ks[1], (4, 32), jnp.float32)
    c = 10.0 * _rand(ks[2], (4, 32), jnp.float32)
    wx = _rand(ks[3], (32, 128), jnp.float32)
    wh = _rand(ks[4], (32, 128), jnp.float32)
    b = _rand(ks[5], (128,), jnp.float32)
    h2, _ = lstm_cell(x, h, c, wx, wh, b)
    assert np.all(np.abs(np.asarray(h2)) <= 1.0 + 1e-6)


# --------------------------------------------------------------------------
# gru_cell
# --------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(
    batch=st.integers(1, 8),
    d_in=st.integers(1, 96),
    hidden=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_gru_matches_ref(batch, d_in, hidden, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = _rand(ks[0], (batch, d_in), jnp.float32)
    h = _rand(ks[1], (batch, hidden), jnp.float32)
    wx = _rand(ks[2], (d_in, 3 * hidden), jnp.float32) * 0.2
    wh = _rand(ks[3], (hidden, 3 * hidden), jnp.float32) * 0.2
    b = _rand(ks[4], (3 * hidden,), jnp.float32) * 0.2
    got = gru_cell(x, h, wx, wh, b)
    want = ref.gru_cell_ref(x, h, wx, wh, b)
    assert got.shape == (batch, hidden)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_gru_interpolates_toward_h():
    """With z → 1 (huge update-gate bias) h' ≈ h."""
    batch, d_in, hidden = 2, 8, 16
    ks = jax.random.split(jax.random.PRNGKey(4), 4)
    x = _rand(ks[0], (batch, d_in), jnp.float32)
    h = _rand(ks[1], (batch, hidden), jnp.float32)
    wx = jnp.zeros((d_in, 3 * hidden))
    wh = jnp.zeros((hidden, 3 * hidden))
    b = jnp.zeros((3 * hidden,)).at[hidden : 2 * hidden].set(50.0)
    got = gru_cell(x, h, wx, wh, b)
    assert_allclose(np.asarray(got), np.asarray(h), rtol=1e-4, atol=1e-4)
