"""L2 correctness: model shapes, output constraints, rollout semantics,
Pallas-vs-ref implementation parity at the full-model level."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import dims, model, synth


@pytest.fixture(scope="module")
def params():
    return model.init_start_params(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def igru_params():
    return model.init_igru_params(jax.random.PRNGKey(1))


@pytest.fixture(scope="module")
def seqs():
    return synth.random_feature_sequences(jax.random.PRNGKey(2), 4)


def test_step_shapes_and_constraints(params, seqs):
    m_h_seq, m_t_seq = seqs
    alpha, beta, state = model.start_step(params, m_h_seq[0], m_t_seq[0], model.zero_state(4))
    assert alpha.shape == (4,) and beta.shape == (4,)
    # Paper: ReLU head, +1 on alpha -> Pareto mean defined, beta positive.
    assert np.all(np.asarray(alpha) > 1.0)
    assert np.all(np.asarray(beta) > 0.0)
    assert len(state) == 4
    for s in state:
        assert s.shape == (4, dims.HIDDEN)
        assert np.all(np.isfinite(np.asarray(s)))


def test_rollout_equals_unrolled_steps(params, seqs):
    """start_rollout(scan) must equal manually chaining start_step."""
    m_h_seq, m_t_seq = seqs
    state = model.zero_state(4)
    for t in range(m_h_seq.shape[0]):
        alpha_u, beta_u, state = model.start_step(params, m_h_seq[t], m_t_seq[t], state)
    alpha_r, beta_r = model.start_rollout(params, m_h_seq, m_t_seq)
    assert_allclose(np.asarray(alpha_r), np.asarray(alpha_u), rtol=1e-5, atol=1e-6)
    assert_allclose(np.asarray(beta_r), np.asarray(beta_u), rtol=1e-5, atol=1e-6)


def test_pallas_and_ref_impl_agree(params, seqs):
    """Full-model parity between the Pallas kernels and the jnp reference —
    this is what justifies training through ref and lowering Pallas."""
    m_h_seq, m_t_seq = seqs
    try:
        model.set_impl(use_pallas=True)
        a_p, b_p = model.start_rollout(params, m_h_seq, m_t_seq)
        model.set_impl(use_pallas=False)
        a_r, b_r = model.start_rollout(params, m_h_seq, m_t_seq)
    finally:
        model.set_impl(use_pallas=True)
    assert_allclose(np.asarray(a_p), np.asarray(a_r), rtol=1e-5, atol=1e-6)
    assert_allclose(np.asarray(b_p), np.asarray(b_r), rtol=1e-5, atol=1e-6)


def test_state_propagates(params, seqs):
    """Different initial states must change the output (LSTM is stateful)."""
    m_h_seq, m_t_seq = seqs
    a0, b0, _ = model.start_step(params, m_h_seq[0], m_t_seq[0], model.zero_state(4))
    ones = tuple(jnp.ones((4, dims.HIDDEN)) for _ in range(4))
    a1, b1, _ = model.start_step(params, m_h_seq[0], m_t_seq[0], ones)
    assert not np.allclose(np.asarray(a0), np.asarray(a1))
    del b0, b1


def test_igru_shapes(igru_params, seqs):
    _, m_t_seq = seqs
    h = jnp.zeros((4, dims.IGRU_HIDDEN))
    pred, h2 = model.igru_step(igru_params, m_t_seq[0], h)
    assert pred.shape == (4, dims.IGRU_OUT)
    assert h2.shape == (4, dims.IGRU_HIDDEN)
    assert np.all(np.asarray(pred) >= 0.0)  # ReLU output


def test_encoder_permutation_sensitivity(params, seqs):
    """Encoder is not permutation invariant over hosts — host identity
    (capacity heterogeneity) matters, per the paper's critique of IGRU-SD."""
    m_h_seq, m_t_seq = seqs
    m_h = m_h_seq[0]
    perm = m_h[:, ::-1, :]
    e1 = model.encoder(params, m_h, m_t_seq[0])
    e2 = model.encoder(params, perm, m_t_seq[0])
    assert not np.allclose(np.asarray(e1), np.asarray(e2))


# --------------------------------------------------------------------------
# Generative model / MLE invariants (python side of the Rust contract)
# --------------------------------------------------------------------------


def test_true_params_ranges(seqs):
    m_h_seq, m_t_seq = seqs
    alpha, beta = synth.true_pareto_params(m_h_seq[-1], m_t_seq[-1])
    a, b = np.asarray(alpha), np.asarray(beta)
    assert np.all(a >= synth.GEN["alpha_min"] - 1e-6)
    assert np.all(a <= synth.GEN["alpha_min"] + synth.GEN["alpha_span"] + 1e-6)
    assert np.all(b > 0)


def test_alpha_decreases_with_load():
    """Heavier load ⇒ heavier tail (smaller α) — the core generative story."""
    m_h = np.zeros((2, dims.N_HOSTS, dims.M_FEATS), np.float32)
    m_t = np.zeros((2, dims.Q_TASKS, dims.P_FEATS), np.float32)
    m_h[..., synth.H_IS_UP] = 1.0
    m_t[..., synth.T_ACTIVE] = 1.0
    m_t[..., synth.T_CPU_REQ] = 0.5
    m_h[0, :, synth.H_CPU_UTIL] = 0.2
    m_h[1, :, synth.H_CPU_UTIL] = 0.9
    alpha, _ = synth.true_pareto_params(jnp.asarray(m_h), jnp.asarray(m_t))
    assert float(alpha[0]) > float(alpha[1])


def test_pareto_mle_recovers_params():
    """Sample → fit round-trip: MLE close to truth for large q."""
    key = jax.random.PRNGKey(5)
    alpha_t, beta_t = 2.5, 1.3
    u = jax.random.uniform(key, (20000,), minval=1e-9, maxval=1.0)
    x = beta_t * u ** (-1.0 / alpha_t)
    alpha_h, beta_h = synth.pareto_mle(x[None, :])
    assert abs(float(alpha_h[0]) - alpha_t) < 0.1
    assert abs(float(beta_h[0]) - beta_t) < 0.01


def test_mle_beta_is_min():
    x = jnp.asarray([[3.0, 1.5, 2.0, 9.0]])
    _, beta = synth.pareto_mle(x)
    assert float(beta[0]) == 1.5
