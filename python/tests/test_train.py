"""Training-loop sanity: loss decreases, Adam behaves, weights round-trip."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from compile import model, synth, train


def test_adam_decreases_quadratic():
    """Hand-rolled Adam minimizes a simple convex objective."""
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = train.adam_init(params)

    def loss_fn(p):
        return jnp.sum((p["w"] - jnp.asarray([1.0, 2.0])) ** 2)

    for _ in range(400):
        grads = jax.grad(loss_fn)(params)
        params, opt = train.adam_update(params, grads, opt, lr=5e-2)
    assert float(loss_fn(params)) < 1e-3


def test_start_training_reduces_loss():
    _, hist = train.train_start(
        jax.random.PRNGKey(0), steps=120, batch=64, log_every=119, log=lambda *_: None
    )
    first, last = hist[0][1], hist[-1][1]
    assert last < 0.7 * first, (first, last)


def test_igru_training_reduces_loss():
    _, hist = train.train_igru(
        jax.random.PRNGKey(0), steps=80, batch=64, log_every=79, log=lambda *_: None
    )
    first, last = hist[0][1], hist[-1][1]
    assert last < 0.9 * first, (first, last)


def test_weights_roundtrip():
    sp = model.init_start_params(jax.random.PRNGKey(1))
    ip = model.init_igru_params(jax.random.PRNGKey(2))
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "w.npz")
        train.save_weights(path, sp, ip)
        sp2, ip2 = train.load_weights(path)
    assert set(sp2) == set(sp) and set(ip2) == set(ip)
    for k in sp:
        np.testing.assert_array_equal(np.asarray(sp[k]), np.asarray(sp2[k]))
    for k in ip:
        np.testing.assert_array_equal(np.asarray(ip[k]), np.asarray(ip2[k]))


def test_trained_model_beats_constant_predictor():
    """After a short training run the model should out-predict the best
    constant (mean) predictor on fresh data — i.e. it actually uses the
    features."""
    params, _ = train.train_start(
        jax.random.PRNGKey(3), steps=600, batch=96, log_every=1000, log=lambda *_: None
    )
    ds = synth.make_dataset(jax.random.PRNGKey(99), 256)
    model.set_impl(use_pallas=False)
    try:
        alpha, beta = model.start_rollout(
            params, jnp.asarray(ds["m_h_seq"]), jnp.asarray(ds["m_t_seq"])
        )
    finally:
        model.set_impl(use_pallas=True)
    a_t = ds["alpha_true"]
    mse_model = float(np.mean((np.asarray(alpha) - a_t) ** 2))
    mse_const = float(np.mean((a_t.mean() - a_t) ** 2))
    assert mse_model < mse_const, (mse_model, mse_const)
    del beta
